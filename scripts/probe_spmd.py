import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh(multi_pod=False)
print("mesh", mesh.shape, "devices", jax.device_count())

W = jax.ShapeDtypeStruct((4096, 8192), jnp.bfloat16)
X = jax.ShapeDtypeStruct((256, 4096), jnp.bfloat16)


def step(w, x):
    y = jnp.einsum("bd,df->bf", x, w, preferred_element_type=jnp.float32)
    return jnp.sum(jax.nn.relu(y))


t0 = time.time()
lowered = jax.jit(
    step,
    in_shardings=(
        NamedSharding(mesh, P("data", "model")),
        NamedSharding(mesh, P("data", None)),
    ),
).lower(W, X)
compiled = lowered.compile()
print("compile_s", round(time.time() - t0, 2))
ma = compiled.memory_analysis()
print("memory_analysis:", ma)
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca
print("flops", ca.get("flops"), "bytes", ca.get("bytes accessed"))
text = compiled.as_text()
print("hlo chars", len(text))
for ln in text.splitlines():
    if "all-" in ln or "reduce-scatter" in ln or "collective" in ln:
        print("COLL:", ln.strip()[:160])
