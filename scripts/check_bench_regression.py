"""CI bench-regression gate for BENCH_engine.json.

Diffs a freshly produced bench result against the committed baseline and
fails (exit 1) when any decode-throughput metric drops more than the
tolerance (default 15%). The comparison table is always printed, one row
per ``*tok_s`` leaf, so a red gate shows exactly which trace regressed and
a green gate still documents the trajectory.

Sections are only compared when both files ran the same trace size (their
``n`` keys match) — a CI smoke at 4 requests is not comparable to a
12-request baseline and is reported as SKIP rather than silently passed.

The long-prompt section additionally carries its own acceptance
invariants, checked from the fresh file alone (they are ratios of two
same-machine runs, so they transfer across runner classes):

* ``stall_p99_reduction >= 2.0`` — chunked prefill must cut the
  per-decode-tick stall p99 at least 2x vs whole-prompt prefill;
* ``decode_tok_s_ratio >= 0.9`` — at no more than a 10% decode
  throughput cost.

The sharded section (multi-device CI job) carries its own fresh-only
invariants the same way:

* ``outputs_identical == true`` — greedy outputs on the mesh must be
  token-identical to the 1-device engine;
* ``capacity.pages_scaling_2x >= 1.9`` — per-device pool capacity must
  scale >= 1.9x from 1 to 2 model shards (the kv-head split really halves
  per-device page bytes).

The autotune section (serving-stack autotuner) likewise carries
fresh-only invariants:

* ``searched_vs_default >= 0.95`` — the searched config's *measured*
  decode tok/s may never fall below 0.95x the hand-picked default (the
  default is in the validation set, so the tuner can only tie or win);
* ``candidates >= 1`` and ``admissible >= 1`` — the search actually
  evaluated something.

Before any comparison both files are **schema-validated**: a bench doc
must carry a ``schema`` version, a non-empty ``config.trace_seeds`` list
(the seeds the traces were drawn from — a doc without them is not
reproducible), and no NaN/Inf anywhere in its numeric leaves (a NaN
tok/s would sail through every ``delta < -tolerance`` comparison as a
silent pass). Validation failures exit 1 before the gate runs.

Absolute tok/s values are machine-dependent: regenerate the committed
baseline (``python -m benchmarks.bench_engine_throughput``) when the CI
runner class changes, or tune ``--tolerance`` via the BENCH_GATE_TOL env
var.

Usage::

    python scripts/check_bench_regression.py \
        --baseline BENCH_engine.json --fresh BENCH_fresh.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

STALL_REDUCTION_MIN = 2.0
TOK_S_RATIO_MIN = 0.9
SHARDED_PAGES_SCALING_MIN = 1.9
AUTOTUNE_RATIO_MIN = 0.95
AUTOTUNE_CANDIDATES_MIN = 1

# required keys of the bench's ``autotune`` section (when present) —
# the gate's floors read these, so a doc that drops one is malformed,
# not merely incomplete
AUTOTUNE_REQUIRED_KEYS = (
    "n",
    "budget",
    "candidates",
    "admissible",
    "default",
    "searched",
    "searched_vs_default",
)


def numeric_leaves(node, path=()):
    """Yield (dotted_path, value) for EVERY numeric leaf (bools excluded)."""
    if isinstance(node, dict):
        for key in sorted(node):
            yield from numeric_leaves(node[key], path + (str(key),))
    elif isinstance(node, list):
        for i, item in enumerate(node):
            yield from numeric_leaves(item, path + (str(i),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield ".".join(path), float(node)


def validate_schema(doc, name="doc"):
    """Structural sanity of one bench document; returns a list of problem
    strings (empty = valid). Checked before any comparison: a NaN leaf
    would pass every ``delta < -tolerance`` check silently, and a doc
    without its trace seeds is not reproducible."""
    problems = []
    if not isinstance(doc, dict):
        return [f"{name}: not a JSON object"]
    if "schema" not in doc:
        problems.append(f"{name}: missing 'schema' version key")
    seeds = (doc.get("config") or {}).get("trace_seeds") \
        if isinstance(doc.get("config"), dict) else None
    if not seeds or not isinstance(seeds, (dict, list)):
        problems.append(
            f"{name}: missing or empty config.trace_seeds "
            "(bench traces must record their seeds)")
    autotune = doc.get("autotune")
    if autotune is not None:
        if not isinstance(autotune, dict):
            problems.append(f"{name}: autotune section is not an object")
        else:
            for key in AUTOTUNE_REQUIRED_KEYS:
                if key not in autotune:
                    problems.append(f"{name}: autotune missing '{key}'")
            for side in ("default", "searched"):
                sub = autotune.get(side)
                if isinstance(sub, dict) and "decode_tok_s" not in sub:
                    problems.append(
                        f"{name}: autotune.{side} missing 'decode_tok_s'")
    for path, value in numeric_leaves(doc):
        if value != value:                       # NaN
            problems.append(f"{name}: NaN at {path}")
        elif value in (float("inf"), float("-inf")):
            problems.append(f"{name}: non-finite value at {path}")
    return problems


def tok_s_leaves(node, path=()):
    """Yield (dotted_path, value) for every numeric ``*tok_s`` leaf."""
    if isinstance(node, dict):
        for key in sorted(node):
            yield from tok_s_leaves(node[key], path + (str(key),))
    elif isinstance(node, (int, float)) and path:
        if path[-1].endswith("tok_s"):
            yield ".".join(path), float(node)


def section_of(path):
    return path.split(".", 1)[0]


def sizes_match(baseline, fresh, section):
    b, f = baseline.get(section), fresh.get(section)
    if not isinstance(b, dict) or not isinstance(f, dict):
        return False
    # a section without a recorded trace size is never comparable
    return b.get("n") is not None and b.get("n") == f.get("n")


def compare(baseline, fresh, tolerance):
    """Build comparison rows; returns (rows, failures)."""
    rows = []
    failures = []
    base_vals = dict(tok_s_leaves(baseline))
    fresh_vals = dict(tok_s_leaves(fresh))
    for path, base in sorted(base_vals.items()):
        section = section_of(path)
        got = fresh_vals.get(path)
        if got is None:
            rows.append((path, base, None, None, "SKIP (missing in fresh)"))
            continue
        if not sizes_match(baseline, fresh, section):
            rows.append((path, base, got, None, "SKIP (trace size differs)"))
            continue
        delta = (got - base) / base if base else 0.0
        if delta < -tolerance:
            status = f"FAIL (> {tolerance:.0%} drop)"
            failures.append(f"{path}: {base:.1f} -> {got:.1f} ({delta:+.1%})")
        else:
            status = "OK"
        rows.append((path, base, got, delta, status))
    for path in sorted(set(fresh_vals) - set(base_vals)):
        rows.append((path, None, fresh_vals[path], None, "NEW (no baseline)"))
    return rows, failures


def check_longprompt(fresh):
    """Acceptance invariants of the chunked-prefill section (fresh-only)."""
    rows = []
    failures = []
    section = fresh.get("longprompt")
    if not isinstance(section, dict):
        return rows, failures
    checks = [
        ("longprompt.stall_p99_reduction", STALL_REDUCTION_MIN),
        ("longprompt.decode_tok_s_ratio", TOK_S_RATIO_MIN),
    ]
    for path, floor in checks:
        value = section.get(path.split(".", 1)[1])
        if value is None:
            rows.append((path, floor, None, None, "SKIP (not recorded)"))
            continue
        if value >= floor:
            rows.append((path, floor, value, None, "OK"))
        else:
            rows.append((path, floor, value, None, f"FAIL (< {floor})"))
            failures.append(f"{path}: {value:.2f} below the {floor} floor")
    return rows, failures


def check_sharded(fresh):
    """Acceptance invariants of the sharded-engine section (fresh-only:
    both are same-machine ratios/booleans, so they transfer across runner
    classes)."""
    rows = []
    failures = []
    section = fresh.get("sharded")
    if not isinstance(section, dict):
        return rows, failures
    path = "sharded.outputs_identical"
    ident = section.get("outputs_identical")
    if ident is None:
        rows.append((path, True, None, None, "SKIP (not recorded)"))
    elif ident:
        rows.append((path, True, True, None, "OK"))
    else:
        rows.append((path, True, False, None, "FAIL (diverged)"))
        failures.append(
            f"{path}: sharded engine diverged from the 1-device engine"
        )
    path = "sharded.capacity.pages_scaling_2x"
    floor = SHARDED_PAGES_SCALING_MIN
    scaling = (section.get("capacity") or {}).get("pages_scaling_2x")
    if scaling is None:
        rows.append((path, floor, None, None, "SKIP (not recorded)"))
    elif scaling >= floor:
        rows.append((path, floor, scaling, None, "OK"))
    else:
        rows.append((path, floor, scaling, None, f"FAIL (< {floor})"))
        failures.append(f"{path}: {scaling:.2f} below the {floor} floor")
    return rows, failures


def check_autotune(fresh):
    """Acceptance invariants of the autotune section (fresh-only: the
    searched/default ratio is two same-machine measurements, so it
    transfers across runner classes)."""
    rows = []
    failures = []
    section = fresh.get("autotune")
    if not isinstance(section, dict):
        return rows, failures
    path = "autotune.searched_vs_default"
    floor = AUTOTUNE_RATIO_MIN
    ratio = section.get("searched_vs_default")
    if ratio is None:
        rows.append((path, floor, None, None, "SKIP (not recorded)"))
    elif ratio >= floor:
        rows.append((path, floor, ratio, None, "OK"))
    else:
        rows.append((path, floor, ratio, None, f"FAIL (< {floor})"))
        failures.append(
            f"{path}: searched config measured {ratio:.2f}x the default "
            f"(floor {floor}x) — the autotuner shipped a regression"
        )
    for key in ("candidates", "admissible"):
        path = f"autotune.{key}"
        floor = AUTOTUNE_CANDIDATES_MIN
        count = section.get(key)
        if count is None:
            rows.append((path, floor, None, None, "SKIP (not recorded)"))
        elif count >= floor:
            rows.append((path, floor, count, None, "OK"))
        else:
            rows.append((path, floor, count, None, f"FAIL (< {floor})"))
            failures.append(
                f"{path}: {count} below the {floor} floor "
                "(the search evaluated nothing)"
            )
    return rows, failures


def _fmt(value):
    if value is None:
        return "-"
    if isinstance(value, (str, bool)):
        return str(value)
    return f"{value:.2f}"


def print_table(rows, headers):
    widths = [len(h) for h in headers]
    rendered = []
    for row in rows:
        cells = [_fmt(value) for value in row]
        rendered.append(cells)
        widths = [max(w, len(c)) for w, c in zip(widths, cells)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for cells in rendered:
        print("  ".join(c.ljust(w) for c, w in zip(cells, widths)))


def main():
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--baseline", default="BENCH_engine.json")
    parser.add_argument("--fresh", required=True)
    parser.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("BENCH_GATE_TOL", "0.15")),
        help="max allowed fractional decode tok/s drop (default 0.15)",
    )
    args = parser.parse_args()

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    problems = validate_schema(baseline, "baseline") \
        + validate_schema(fresh, "fresh")
    if problems:
        for problem in problems:
            print(f"SCHEMA: {problem}")
        return 1

    rows, failures = compare(baseline, fresh, args.tolerance)
    table = [
        (
            path,
            base,
            got,
            None if delta is None else f"{delta:+.1%}",
            status,
        )
        for path, base, got, delta, status in rows
    ]
    print(f"bench gate: tolerance {args.tolerance:.0%} decode tok/s drop")
    print_table(table, ("metric", "baseline", "fresh", "delta", "status"))

    lp_rows, lp_failures = check_longprompt(fresh)
    failures.extend(lp_failures)
    if lp_rows:
        print()
        print("chunked-prefill acceptance (fresh run, machine-independent):")
        print_table(
            [(p, f, v, s) for p, f, v, _, s in lp_rows],
            ("invariant", "floor", "value", "status"),
        )

    sh_rows, sh_failures = check_sharded(fresh)
    failures.extend(sh_failures)
    if sh_rows:
        print()
        print("sharded-engine acceptance (fresh run, machine-independent):")
        print_table(
            [(p, f, v, s) for p, f, v, _, s in sh_rows],
            ("invariant", "floor", "value", "status"),
        )

    at_rows, at_failures = check_autotune(fresh)
    failures.extend(at_failures)
    if at_rows:
        print()
        print("autotune acceptance (fresh run, machine-independent):")
        print_table(
            [(p, f, v, s) for p, f, v, _, s in at_rows],
            ("invariant", "floor", "value", "status"),
        )

    print()
    if failures:
        for failure in failures:
            print(f"REGRESSION: {failure}")
        return 1
    print("bench gate: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
