"""Dev smoke: prefill(S) + decode(1) logits == forward(S+1) last-position."""

import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, tiny_config
from repro.models.api import build_model

B, S = 2, 48  # S > tiny window (32) to exercise the ring cache


def main():
    names = sys.argv[1:] or [
        n
        for n in ARCHS
        if n
        not in ("supernet-lm", "whisper-large-v3", "llava-next-mistral-7b")
    ]
    key = jax.random.PRNGKey(0)
    for name in names:
        cfg = tiny_config(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(3))
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        full_logits, _, _, _ = model.forward(
            params, {"tokens": toks}, want_cache=False
        )
        want = full_logits[:, -1]

        _, cache = model.prefill(params, {"tokens": toks[:, :S]})

        # grow full-attention caches by 1 slot so decode can write at pos=S
        def grow(path, a):
            keystr = jax.tree_util.keystr(path)
            if a.ndim == 5 and a.shape[2] == S and "mamba" not in keystr:
                pad = [(0, 0)] * 5
                pad[2] = (0, 1)
                return jnp.pad(a, pad)
            return a

        cache = jax.tree_util.tree_map_with_path(grow, cache)
        got, _ = model.decode_step(
            params, cache, toks[:, S : S + 1], jnp.asarray(S, jnp.int32)
        )
        got = got[:, 0]
        err = float(jnp.max(jnp.abs(want - got)))
        rel = err / (float(jnp.max(jnp.abs(want))) + 1e-9)
        print(
            f"{name:28s} max_abs_err={err:.5f} rel={rel:.5f} "
            f"{'OK' if rel < 2e-2 else 'FAIL'}"
        )
        assert rel < 2e-2, name


if __name__ == "__main__":
    main()
