"""Dev smoke: flash_attention == dense reference, fwd + grads."""

import jax
import jax.numpy as jnp

from repro.models.attention import _attend, causal_mask, local_mask
from repro.models.flash import flash_attention

B, S, H, K, hd = 2, 256, 4, 2, 16


def dense(q, k, v, kind, window, cap):
    if kind == "local":
        m = local_mask(S, S, window)
    elif kind == "bidir":
        m = jnp.ones((1, 1, S, S), bool)
    else:
        m = causal_mask(S, S)
    return _attend(q, k, v, m, cap)


def main():
    key = jax.random.PRNGKey(0)
    kq, kk, kv, kd = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, K, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, K, hd), jnp.float32)
    do = jax.random.normal(kd, (B, S, H, hd), jnp.float32)

    for kind, window, cap in [
        ("global", 0, 0.0),
        ("local", 64, 0.0),
        ("bidir", 0, 0.0),
        ("global", 0, 20.0),
        ("local", 100, 30.0),
    ]:
        f = lambda q, k, v: jnp.sum(
            flash_attention(q, k, v, kind, window, cap, 64, 64) * do
        )
        g = lambda q, k, v: jnp.sum(dense(q, k, v, kind, window, cap) * do)
        of, gf = jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)
        od, gd = jax.value_and_grad(g, argnums=(0, 1, 2))(q, k, v)
        err_o = abs(float(of - od)) / (abs(float(od)) + 1e-9)
        errs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(gf, gd)]
        ok = err_o < 1e-3 and all(e < 1e-3 for e in errs)
        print(
            f"{kind:8s} W={window:4d} cap={cap:5.1f} "
            f"out_rel={err_o:.2e} dgrad_max={max(errs):.2e} "
            f"{'OK' if ok else 'FAIL'}"
        )
        assert ok


if __name__ == "__main__":
    main()
