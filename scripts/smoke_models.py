"""Dev smoke: tiny config of every arch — forward, loss+grad, prefill,
decode."""

import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, tiny_config
from repro.models.api import build_model

B, S = 2, 32


def batch_for(model, cfg):
    key = jax.random.PRNGKey(1)
    if cfg.is_encdec:
        Sd = max(S // cfg.dec_ratio, 2)
        return {
            "frames": jax.random.normal(
                key, (B, S, cfg.d_model), jnp.bfloat16
            ),
            "tokens": jnp.ones((B, Sd), jnp.int32),
            "labels": jnp.ones((B, Sd), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        Sp = int(S * cfg.patch_frac)
        return {
            "patches": jax.random.normal(
                key, (B, Sp, cfg.d_model), jnp.bfloat16
            ),
            "tokens": jnp.ones((B, S - Sp), jnp.int32),
            "labels": jnp.ones((B, S - Sp), jnp.int32),
        }
    return {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }


def main():
    names = sys.argv[1:] or [n for n in ARCHS if n != "supernet-lm"]
    for name in names:
        cfg = tiny_config(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = batch_for(model, cfg)
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        logits, cache = model.prefill(params, batch)
        tok = jnp.ones((B, 1), jnp.int32)
        lg2, cache2 = model.decode_step(
            params, cache, tok, jnp.asarray(S, jnp.int32)
        )
        ok = (
            jnp.isfinite(loss)
            & jnp.isfinite(gnorm)
            & jnp.all(jnp.isfinite(lg2))
        )
        print(
            f"{name:28s} loss={float(loss):8.4f} "
            f"gnorm={float(gnorm):10.4f} "
            f"params={model.param_count():,} decode_logits={lg2.shape} "
            f"{'OK' if bool(ok) else 'FAIL'}"
        )
        assert bool(ok), name


if __name__ == "__main__":
    main()
