import os

# Smoke tests and benches must see the single real CPU device (the dry-run
# sets its own 512-device flag in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_batch(cfg, B=2, S=32, seed=1):
    key = jax.random.PRNGKey(seed)
    if cfg.is_encdec:
        Sd = max(S // cfg.dec_ratio, 2)
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.bfloat16),
            "tokens": jnp.ones((B, Sd), jnp.int32),
            "labels": jnp.ones((B, Sd), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        Sp = int(S * cfg.patch_frac)
        return {
            "patches": jax.random.normal(key, (B, Sp, cfg.d_model),
                                         jnp.bfloat16),
            "tokens": jnp.ones((B, S - Sp), jnp.int32),
            "labels": jnp.ones((B, S - Sp), jnp.int32),
        }
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}
