"""Engine telemetry (serving/telemetry): metrics instruments, the
recorder's tick/span/stall record, roofline calibration, Chrome trace
export, back-compat views (stall_log / first_token_s), and the engine
integration — including the JitLRU no-retrace steady-state guarantee
and per-shard mesh tags."""

import itertools
import json

import jax
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.models.api import build_model
from repro.serving.engine import AdmissionPolicy, Engine, Request
from repro.serving.telemetry import (MetricsRegistry, RecordingSink,
                                     Telemetry, TickEvent, calibrate,
                                     chrome_trace, summarize,
                                     write_chrome_trace)


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _req(rid, S, gen, *, vocab=512):
    rng = np.random.default_rng(rid)
    return Request(rid=rid, prompt=rng.integers(2, vocab, S, dtype=np.int64)
                   .astype(np.int32), max_new=gen)


def _fake_clock():
    """Deterministic 1-second-per-call clock for recorder unit tests."""
    counter = itertools.count()
    return lambda: float(next(counter))


def _tick(kind="decode", step=1, t=0.0, measured=1.0, predicted=0.5,
          batch=2, padded=4, q_len=1, **kw):
    return TickEvent(kind=kind, step=step, t_start=t, measured_s=measured,
                     predicted_s=predicted, batch=batch, padded_batch=padded,
                     q_len=q_len, tokens=batch, **kw)


# ---------------------------------------------------------------- metrics --
def test_counter_gauge_histogram():
    m = MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(4)
    assert m.counter("c").value == 5

    g = m.gauge("g")
    for v in (3.0, 1.0, 7.0):
        g.set(v)
    assert (g.value, g.min, g.max) == (7.0, 1.0, 7.0)

    h = m.histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(50) == 50.0
    assert h.percentile(99) == 99.0
    assert h.count == 100 and h.mean == 50.5
    assert m.histogram("empty").percentile(50) == 0.0


def test_registry_reset_preserves_references():
    """A monitor holding an instrument across Engine.reset_stats must see
    zeroed state through the SAME object (create-on-use would silently
    fork it otherwise)."""
    m = MetricsRegistry()
    c, g, h = m.counter("c"), m.gauge("g"), m.histogram("h")
    c.inc(3)
    g.set(2.0)
    h.observe(1.0)
    m.reset()
    assert m.counter("c") is c and c.value == 0
    assert m.gauge("g") is g and g.value is None
    assert m.histogram("h") is h and h.count == 0


def test_histogram_maxlen_bound():
    from repro.serving.telemetry import Histogram
    h = Histogram(maxlen=8)
    for v in range(100):
        h.observe(float(v))
    assert len(h.samples) <= 8
    assert h.count == 100            # count/total keep the full history


# --------------------------------------------------------------- recorder --
def test_recorder_ticks_spans_and_views():
    tel = Telemetry(clock=_fake_clock())
    tel.start_clock()                         # t0 = 0.0
    tel.seq_event(7, "enqueue", prompt=8)     # t = 1.0
    tel.seq_event(7, "admit", slot=0)         # t = 2.0
    tel.tick(_tick(kind="chunk", q_len=16))
    tel.seq_event(7, "first_token", token=3)  # t = 3.0
    tel.stall(0.25, 0.125)
    tel.tick(_tick(kind="decode"))

    assert [e.kind for e in tel.ticks] == ["chunk", "decode"]
    assert tel.metrics.counter("ticks.decode").value == 1
    assert tel.metrics.counter("ticks.chunk").value == 1
    assert tel.stall_log_view() == [0.25]
    assert tel.first_token_view() == {7: 3.0}
    assert tel.queue_wait_seconds() == [1.0]
    span = tel.spans[7]
    assert [e.kind for e in span.events] == ["enqueue", "admit",
                                             "first_token"]

    tel.reset()
    assert not tel.ticks and not tel.spans and not tel.stalls
    assert tel.t0 is None
    assert tel.metrics.counter("ticks.decode").value == 0


def test_recorder_first_token_keeps_first_edge():
    """A preempted request re-prefills and emits a second first_token
    edge; the TTFT view must keep the first (the token was already
    served once)."""
    tel = Telemetry(clock=_fake_clock())
    tel.start_clock()
    tel.seq_event(0, "first_token", token=1)   # t = 1.0
    tel.seq_event(0, "preempt")
    tel.seq_event(0, "requeue")
    tel.seq_event(0, "first_token", token=1)   # t = 4.0 (recompute)
    assert tel.first_token_view() == {0: 1.0}
    assert tel.spans[0].count("first_token") == 2


def test_recording_sink_sees_the_stream():
    sink = RecordingSink()
    tel = Telemetry(sink=sink)
    tel.tick(_tick())
    tel.seq_event(1, "enqueue")
    assert len(sink.ticks) == 1 and sink.ticks[0].kind == "decode"
    assert sink.seq_events[0][0] == 1


# -------------------------------------------------------------- calibrate --
def test_calibrate_recovers_scale():
    """measured = 2 * predicted exactly -> scale 2.0, rel_err 1.0."""
    ticks = [_tick(measured=2.0 * p, predicted=p, t=float(i))
             for i, p in enumerate((0.5, 1.0, 1.5))]
    report = calibrate(ticks)
    (g,) = report.groups
    assert g.kind == "decode" and g.n == 3
    assert g.scale == pytest.approx(2.0)
    assert g.rel_err == pytest.approx(1.0)
    assert report.scale_factors()["decode"] == pytest.approx(2.0)
    assert report.rel_err_by_kind()["decode"] == pytest.approx(1.0)
    assert "scale[decode]" in report.format()


def test_calibrate_unpredicted_group_is_none():
    """hw_name='test' policies predict 0.0 — measured percentiles still
    report, scale/rel_err must be None (not inf/nan)."""
    ticks = [_tick(measured=0.5, predicted=0.0),
             _tick(kind="chunk", q_len=16, measured=1.0, predicted=0.5)]
    report = calibrate(ticks)
    scales = report.scale_factors()
    assert scales["decode"] is None
    assert scales["chunk"] == pytest.approx(2.0)
    d = report.as_dict()
    # JSON-safe: the bench serializes this with allow_nan semantics
    json.dumps(d, allow_nan=False)


def test_calibrate_groups_by_shape():
    ticks = [_tick(padded=4, measured=1.0, predicted=1.0),
             _tick(padded=8, measured=2.0, predicted=1.0)]
    report = calibrate(ticks)
    assert {(g.batch, g.q_len) for g in report.groups} == {(4, 1), (8, 1)}
    # sample-weighted per-kind scale blends both groups
    assert report.scale_factors()["decode"] == pytest.approx(1.5)


# ----------------------------------------------------------------- engine --
@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_records_ticks_and_spans(gemma_tiny, tmp_path):
    model, params = gemma_tiny
    engine = Engine(model, params, _policy())
    reqs = [_req(0, 20, 4), _req(1, 8, 6)]
    engine.run(reqs)

    tel = engine.telemetry
    kinds = {ev.kind for ev in tel.ticks}
    assert kinds == {"chunk", "decode"}       # chunked mode, no buckets
    assert all(ev.measured_s > 0.0 for ev in tel.ticks)
    # hw_name="test" is unknown to the roofline -> no prediction
    assert all(ev.predicted_s == 0.0 for ev in tel.ticks)
    decode = [ev for ev in tel.ticks if ev.kind == "decode"]
    assert all(ev.padded_batch == 4 for ev in decode)
    assert all(0 < ev.batch <= ev.padded_batch for ev in decode)
    assert sum(ev.tokens for ev in decode) == engine.stats["decode_tokens"]
    # the first chunk tick carries the admissions' page allocations
    chunks = [ev for ev in tel.ticks if ev.kind == "chunk"]
    assert chunks[0].pages_allocated > 0
    # every page returned by drain: lifetime counters agree
    a = engine.kv.allocator
    assert a.total_allocated == a.total_freed

    # spans: full lifecycle for both requests
    for r in reqs:
        span = tel.spans[r.rid]
        for kind in ("enqueue", "admit", "first_token", "finish",
                     "release"):
            assert span.count(kind) == 1, (r.rid, kind)
        assert span.count("chunk") == -(-len(r.prompt) // 16)
    assert set(engine.first_token_s) == {0, 1}
    assert all(t >= 0.0 for t in engine.first_token_s.values())
    assert engine.stall_log == tel.stall_log_view()

    # metrics rolled up
    m = tel.metrics
    assert m.counter("ticks.decode").value == engine.stats["decode_ticks"]
    assert m.gauge("pool.occupancy").value is not None
    assert m.gauge("pool.min_free").value is not None
    assert "telemetry summary" in summarize(tel)

    # reset drops the record (bench re-timing path)
    engine.reset_stats()
    assert not tel.ticks and not tel.spans and engine.stall_log == []
    assert engine.first_token_s == {}


def test_engine_chrome_trace_is_valid(gemma_tiny, tmp_path):
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2))
    engine.run([_req(0, 20, 4), _req(1, 8, 3)])

    path = tmp_path / "trace.json"
    write_chrome_trace(engine.telemetry, str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    # finite by construction: re-serialization with allow_nan=False holds
    json.dumps(doc, allow_nan=False)

    slices = [e for e in evs if e.get("ph") == "X"]
    assert slices and all(e["dur"] > 0.0 for e in slices)
    assert {e["name"] for e in slices} == {"chunk", "decode"}
    counters = [e for e in evs if e.get("ph") == "C"]
    assert {e["name"] for e in counters} == {"pool free pages",
                                             "queue depth"}
    # async request spans balance per id
    begins = [e["id"] for e in evs if e.get("ph") == "b"]
    ends = [e["id"] for e in evs if e.get("ph") == "e"]
    assert sorted(begins) == sorted(ends) == [0, 1]
    marks = [e for e in evs if e.get("ph") == "n"]
    assert {m["args"]["event"] for m in marks} >= {"admit", "chunk",
                                                   "first_token", "finish"}


def test_engine_preemption_span_and_ttft(gemma_tiny):
    """Forced preemption (pool too small for both lifetimes): the victim's
    span records preempt/requeue, its TTFT keeps the first served token,
    and the decode tick that preempted carries the page deltas."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2, num_pages=7))
    engine.run([_req(0, 12, 44), _req(1, 12, 44)])
    assert engine.stats["preemptions"] >= 1

    tel = engine.telemetry
    victims = [rid for rid, s in tel.spans.items() if s.count("preempt")]
    assert victims
    for rid in victims:
        span = tel.spans[rid]
        assert span.count("requeue") == span.count("preempt")
        assert span.count("admit") == span.count("preempt") + 1
        if span.count("first_token") > 1:
            # TTFT pinned to the FIRST first_token edge
            first = span.first("first_token").t
            assert engine.first_token_s[rid] == tel.rel(first)
    preempt_ticks = [ev for ev in tel.ticks if ev.preempted]
    assert preempt_ticks and all(ev.kind == "decode"
                                 for ev in preempt_ticks)
    assert sum(ev.preempted for ev in tel.ticks) == \
        engine.stats["preemptions"]
    assert tel.metrics.counter("preemptions").value == \
        engine.stats["preemptions"]
    # low-water mark: the pool really was driven near empty
    assert tel.metrics.gauge("pool.min_free").value <= 1


def test_engine_steady_state_decode_never_retraces(gemma_tiny):
    """Satellite guarantee: after warmup, decode ticks reuse ONE compiled
    executable — the jit cache-size gauge stays at 1 and the per-shape
    LRUs see no new misses across a second identical run."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2))
    reqs = [_req(0, 20, 6), _req(1, 8, 4)]
    engine.run(reqs)
    m = engine.telemetry.metrics
    decode_cache = m.gauge("jit.decode.cache_size").value
    chunk_cache = m.gauge("jit.chunk.cache_size").value
    if decode_cache >= 0:                 # PjitFunction exposes _cache_size
        assert decode_cache == 1.0
    if chunk_cache >= 0:
        assert chunk_cache == 1.0
    misses_before = engine._prefill_jits.misses
    writer_misses_before = engine.kv._write_jit.misses

    engine.reset_stats()
    engine.run(reqs)                      # steady state: same shapes
    if decode_cache >= 0:
        assert m.gauge("jit.decode.cache_size").value == 1.0
    assert engine._prefill_jits.misses == misses_before
    assert engine.kv._write_jit.misses == writer_misses_before
    # chunked mode: no padding-bucket jits at all, so misses stay 0 and
    # the hit/miss gauges report the same
    assert m.gauge("jit.prefill.misses").value == 0.0


def test_engine_mesh_tags_on_ticks(gemma_tiny):
    """A 1x1 mesh engine stamps every tick event with its shard layout
    (the multi-device CI job exercises real meshes; the tags ride the
    same path here on one device)."""
    from repro.launch.mesh import make_serving_mesh
    model, params = gemma_tiny
    mesh = make_serving_mesh(model=1, data=1)
    engine = Engine(model, params, _policy(max_batch=2), mesh=mesh)
    engine.run([_req(0, 8, 3)])
    assert engine.telemetry.ticks
    for ev in engine.telemetry.ticks:
        assert ev.tags["mesh_model"] == 1
        assert ev.tags["mesh_data"] == 1
        assert ev.tags["mesh_devices"] == 1
    # tags survive into the Chrome trace slice args
    doc = chrome_trace(engine.telemetry)
    x = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert all(e["args"]["mesh_model"] == 1 for e in x)


def test_engine_roofline_prediction_on_known_hw(gemma_tiny):
    """With a real hardware target the predictor prices every tick kind
    (> 0), predictions are constant per shape, and calibrate() fits a
    finite scale."""
    from repro.core.hardware_model import V5E_EDGE
    from repro.serving.engine import derive_policy
    import dataclasses
    model, params = gemma_tiny
    policy = derive_policy(model.cfg, V5E_EDGE, max_model_len=64,
                           param_bytes=model.param_bytes())
    policy = dataclasses.replace(policy, max_batch=2)
    engine = Engine(model, params, policy)
    engine.run([_req(0, 20, 4), _req(1, 8, 3)])
    tel = engine.telemetry
    assert all(ev.predicted_s > 0.0 for ev in tel.ticks)
    for kind in ("chunk", "decode"):
        preds = {ev.predicted_s for ev in tel.ticks if ev.kind == kind
                 and ev.padded_batch == 2}
        assert len(preds) <= 1            # memoized per shape
    report = calibrate(tel.ticks)
    for kind, scale in report.scale_factors().items():
        assert scale is not None and np.isfinite(scale) and scale > 0.0
    assert tel.metrics.histogram("tick.decode.rel_err").count > 0
