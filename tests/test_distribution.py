"""Sharding rules, mesh construction, roofline HLO parsing, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shlib
from repro.distributed.fault_tolerance import shrink_mesh
from repro.roofline.analysis import (Roofline, collective_bytes,
                                     model_flops_for, active_params)
from repro.configs.base import SHAPES


def _mesh_2d(data=4, model=4):
    n = data * model
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip("needs forced host devices; covered by the dry-run")
    return Mesh(np.asarray(devs[:n]).reshape(data, model),
                ("data", "model"))


class FakeMesh:
    """Shape-only stand-in so rule tests don't need real devices."""
    def __init__(self, **axes):
        self.shape = axes


def test_choose_spec_divisibility_fallbacks():
    mesh = FakeMesh(data=16, model=16)
    # granite vocab 49155 is NOT divisible -> vocab falls to replicated,
    # embed dim takes fsdp(data)
    spec = shlib.choose_spec((49155, 4096), ("vocab", "embed"), mesh)
    assert spec == P(None, "data")
    # padded vocab shards over model
    spec = shlib.choose_spec((49408, 4096), ("vocab", "embed"), mesh)
    assert spec == P("model", "data")
    # gemma2 8 q heads < 16 -> heads replicated
    spec = shlib.choose_spec((2304, 8, 256), ("embed", "heads", "head_dim"),
                             mesh)
    assert spec == P("data")
    # 32 heads shard over model
    spec = shlib.choose_spec((4096, 32, 128), ("embed", "heads", "head_dim"),
                             mesh)
    assert spec == P("data", "model")
    # no mesh axis used twice in one tensor
    spec = shlib.choose_spec((128, 64, 64), ("d_ff", "experts", "expert_ff"),
                             mesh)
    assert tuple(spec).count("model") <= 1


def test_choose_spec_decode_cache():
    mesh = FakeMesh(data=16, model=16)
    # granite decode cache: kv=8 unshardable -> cache_seq takes model
    spec = shlib.choose_spec((40, 128, 32768, 8, 128),
                             ("layer", "batch", "cache_seq", "kv_heads",
                              "head_dim"), mesh)
    assert spec == P(None, "data", "model")
    # batch=1 long-context: seq dim falls back to data
    spec = shlib.choose_spec((1, 524288), ("batch", "seq"), mesh)
    assert spec == P(None, "data")


def test_multipod_fsdp_axes():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = shlib.choose_spec((49408, 4096), ("vocab", "embed"), mesh)
    assert spec == P("model", ("pod", "data"))


def test_collective_parser():
    hlo = """
  %all-gather = f32[4096,512]{1,0} all-gather(%x), replica_groups=[16,16]
  %ar = bf16[1024]{0} all-reduce(%y), to_apply=%sum
  ROOT %rs = (f32[8,4]{1,0}, f32[4]{0}) reduce-scatter(%a, %b)
  %not_a_collective = f32[2,2]{1,0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 4096 * 512 * 4
    assert out["all-reduce"] == 1024 * 2 * 2          # bf16, 2x for AR
    assert out["reduce-scatter"] == (8 * 4 + 4) * 4
    assert out["count"] == 3


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_global=197e12 * 256, bytes_global=1e9,
                 coll_bytes_global=1e9, chips=256, model_flops=100e12 * 256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert r.bottleneck == "compute"
    assert 0.49 < r.mfu_bound < 0.52


def test_model_flops():
    cfg = get_config("granite-3-8b")
    f_train = model_flops_for(cfg, SHAPES["train_4k"])
    f_pref = model_flops_for(cfg, SHAPES["prefill_32k"])
    assert f_train / f_pref == pytest.approx(3.0, rel=0.01)  # 6ND vs 2ND
    moe = get_config("llama4-maverick-400b-a17b")
    assert active_params(moe) < 0.06 * moe.param_count()


def test_shrink_mesh():
    m = shrink_mesh(jax.device_count(), model_axis=1)
    assert m.shape["data"] == jax.device_count()
    assert m.shape["model"] == 1


# ----------------------------------------- choose_spec/specs_for direct --
# (previously only exercised via launch/dryrun.py; the sharded serving
# engine now builds its shard_map specs from these rules, so the
# invariants get their own property coverage.)

def test_specs_for_structure_and_replication():
    """specs_for mirrors the abstract pytree, honors None logical entries
    (fully replicated), and returns NamedShardings on the given mesh."""
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    abstract = {
        "a": jax.ShapeDtypeStruct((8, 4), jnp.float32),
        "nest": {"b": jax.ShapeDtypeStruct((2, 2, 2), jnp.bfloat16)},
    }
    logical = {"a": ("vocab", "embed"), "nest": {"b": None}}
    specs = shlib.specs_for(abstract, logical, mesh)
    assert set(specs) == {"a", "nest"}
    assert specs["nest"]["b"].spec == P()
    for s in jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "spec")):
        assert s.mesh.shape == mesh.shape
    # a 1-sized mesh axis always divides: both dims place
    assert specs["a"].spec == P("model", "data")


def _spec_axes(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


_LOGICAL = sorted(shlib.CANDIDATES) + [None]
_DIMS = [1, 2, 3, 4, 6, 8, 12, 16, 48, 49]


def check_choose_spec_invariants(shape, logical, mesh):
    """For one (shape, logical axes, mesh): (a) no mesh axis is used twice
    within one tensor — divisibility fall-through included; (b) every
    placement divides its dim by the mesh-axes product; (c) replicate
    really is the last resort: a dim is left None only when every
    candidate is absent, already used (by an earlier dim — the walk is
    left-to-right), or non-dividing."""
    spec = shlib.choose_spec(shape, logical, mesh)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))

    used = []
    for e in entries:
        used.extend(_spec_axes(e))
    assert len(used) == len(set(used)), (shape, logical, spec)

    taken: set = set()
    for dim, name, e in zip(shape, logical, entries):
        placed = _spec_axes(e)
        if placed:
            size = int(np.prod([mesh.shape[a] for a in placed]))
            assert dim % size == 0, (shape, logical, spec)
        else:
            for cand in shlib.CANDIDATES.get(name or "", []):
                present = tuple(a for a in cand if a in mesh.shape)
                if not present:
                    continue
                if any(a in taken for a in present):
                    continue
                size = int(np.prod([mesh.shape[a] for a in present]))
                assert dim % size != 0, (
                    f"dim {dim} ({name}) replicated although {present} "
                    f"was free and divides: {shape} {logical} {spec}")
        taken.update(placed)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(data=st.data(),
           ndim=st.integers(1, 4),
           pod=st.sampled_from([0, 2]),
           dsize=st.sampled_from([1, 2, 3, 4, 16]),
           msize=st.sampled_from([1, 2, 3, 4, 16]))
    def test_choose_spec_invariants(data, ndim, pod, dsize, msize):
        axes = {"data": dsize, "model": msize}
        if pod:
            axes["pod"] = pod
        shape = tuple(data.draw(st.sampled_from(_DIMS), label=f"dim{i}")
                      for i in range(ndim))
        logical = tuple(data.draw(st.sampled_from(_LOGICAL),
                                  label=f"log{i}") for i in range(ndim))
        check_choose_spec_invariants(shape, logical, FakeMesh(**axes))

    test_choose_spec_invariants.__doc__ = \
        check_choose_spec_invariants.__doc__
else:                        # loud skip, same as the -ra convention
    @pytest.mark.skip(reason="optional dep: property test needs hypothesis")
    def test_choose_spec_invariants():
        pass


def test_choose_spec_invariants_seeded_fuzz():
    """Hypothesis-free fallback sweep of the same invariants (runs
    everywhere, including environments without the optional dep)."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        axes = {"data": int(rng.choice([1, 2, 3, 4, 16])),
                "model": int(rng.choice([1, 2, 3, 4, 16]))}
        if rng.integers(2):
            axes["pod"] = 2
        ndim = int(rng.integers(1, 5))
        shape = tuple(int(rng.choice(_DIMS)) for _ in range(ndim))
        logical = tuple(
            _LOGICAL[int(rng.integers(len(_LOGICAL)))]
            for _ in range(ndim))
        check_choose_spec_invariants(shape, logical, FakeMesh(**axes))


def test_sharded_train_step_runs_on_host_mesh():
    """End-to-end pjit train step on a (n,1) host mesh (1 device in CI)."""
    from repro.configs import tiny_config
    from repro.configs.base import TrainConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models.api import build_model
    from repro.training import steps as steps_lib
    from conftest import tiny_batch

    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    tcfg = TrainConfig()
    mesh = make_host_mesh()
    ac = shlib.make_ac(mesh)
    state = steps_lib.init_train_state(model, tcfg, jax.random.PRNGKey(0))
    sspecs = shlib.specs_for(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        steps_lib.train_state_logical_specs(model, tcfg), mesh)
    state = jax.device_put(state, sspecs)
    step = jax.jit(steps_lib.make_train_step(model, tcfg, ac=ac))
    batch = tiny_batch(cfg, B=2, S=32)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
