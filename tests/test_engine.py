"""Continuous-batching engine: scheduler admission/eviction/backfill,
roofline admission policy, paged-pool bookkeeping, and greedy equivalence
with the sequential baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, tiny_config
from repro.core.hardware_model import V5E_EDGE, V5E_POD
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.serving.engine import (AdmissionPolicy, Engine, PageAllocator,
                                  Request, Scheduler, derive_policy)


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _req(rid, S, gen, *, vocab=512, arrival=0.0, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(2, vocab, S, dtype=np.int64)
                   .astype(np.int32), max_new=gen, arrival=arrival)


def _sched(max_batch=2, num_pages=9, page_size=16, max_len=64):
    return Scheduler(PageAllocator(num_pages, page_size), max_batch, max_len)


# -------------------------------------------------------------- scheduler --
def test_admission_respects_max_batch():
    s = _sched(max_batch=2, num_pages=100)
    for i in range(4):
        s.submit(_req(i, 8, 8))
    admitted = s.admit()
    assert [a.req.rid for a in admitted] == [0, 1]   # FIFO order
    assert s.num_active == 2 and s.num_queued == 2
    assert s.admit() == []                            # slots full


def test_admission_respects_page_budget():
    # 8 usable pages (page 0 is scratch); each request needs 3 pages.
    s = _sched(max_batch=4, num_pages=9, page_size=16)
    for i in range(3):
        s.submit(_req(i, 20, 20))                     # 40 tokens -> 3 pages
    admitted = s.admit()
    assert len(admitted) == 2                         # 3rd doesn't fit
    assert s.allocator.num_free == 2
    assert all(0 not in a.pages for a in admitted)    # scratch never leased


def test_eviction_frees_pages_and_backfills():
    s = _sched(max_batch=2, num_pages=9, page_size=16)
    for i in range(3):
        s.submit(_req(i, 20, 20))
    first = s.admit()
    assert s.admit() == []
    s.release(first[0])
    assert s.allocator.num_free == 5
    backfilled = s.admit()
    assert [a.req.rid for a in backfilled] == [2]
    assert backfilled[0].slot == first[0].slot        # slot reused


def test_admission_respects_arrival_times():
    s = _sched(max_batch=4, num_pages=100)
    s.submit(_req(0, 8, 8, arrival=0.0))
    s.submit(_req(1, 8, 8, arrival=5.0))
    assert [a.req.rid for a in s.admit(now=1.0)] == [0]
    assert [a.req.rid for a in s.admit(now=6.0)] == [1]


def test_submit_rejects_oversized_request():
    s = _sched(max_len=32)
    with pytest.raises(ValueError):
        s.submit(_req(0, 30, 10))


# ------------------------------------------------------- admission policy --
def test_admission_policy_haq_quant_on_edge():
    """8B params at bf16 (~16 GiB) can't fit the edge chip's HBM next to a
    4k sequence -> policy demands the HAQ int8 policy; the pod doesn't."""
    cfg = get_config("granite-3-8b")
    edge = derive_policy(cfg, V5E_EDGE, max_model_len=4096)
    pod = derive_policy(cfg, V5E_POD, max_model_len=4096)
    assert edge.quant_bits == 8
    assert pod.quant_bits == 16
    assert pod.max_batch > edge.max_batch
    assert pod.prefill_chunk >= edge.prefill_chunk
    assert edge.est_decode_s <= edge.decode_slo_s


def test_admission_policy_pages_fit_hbm():
    cfg = get_config("gemma2-2b")
    pol = derive_policy(cfg, V5E_EDGE, max_model_len=4096)
    from repro.serving.engine.admission import kv_bytes_per_token
    kv_bytes = (pol.num_pages - 1) * pol.page_size * kv_bytes_per_token(cfg)
    assert kv_bytes + cfg.param_count() * 2 * pol.quant_bits / 16 \
        <= V5E_EDGE.hbm_bytes
    # the pool must always hold >= 1 full-length sequence, else a legal
    # request could wait on page allocation forever
    assert pol.num_pages - 1 >= pol.pages_per_seq


# ----------------------------------------------------------------- engine --
@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_matches_sequential_greedy(gemma_tiny):
    """Mixed-length continuous batching is token-identical to serving each
    request alone through the dense sequential baseline."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy())
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(7):
        S = int(rng.integers(4, 44))     # spans the local window (32)
        gen = int(rng.integers(2, 16))
        reqs.append(Request(rid=i, prompt=rng.integers(
            2, model.cfg.vocab_size, S).astype(np.int32), max_new=gen))
    outs = engine.run(reqs)
    assert engine.stats["admitted"] == len(reqs)
    # batched: strictly fewer decode ticks than total decoded tokens
    assert engine.stats["decode_ticks"] < engine.stats["decode_tokens"]
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]), r.max_new)[0])
        got = outs[r.rid]
        assert got.shape == (len(r.prompt) + r.max_new,)
        assert np.array_equal(want, got), r.rid


def test_engine_backfills_mid_flight(gemma_tiny):
    """With 2 slots and 3 requests, the short request finishes first and the
    queued one backfills while the long one is still decoding."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2))
    reqs = [_req(0, 8, 2), _req(1, 8, 24), _req(2, 8, 2)]
    for r in reqs:
        engine.submit(r)
    finish_order = []
    while engine.scheduler.has_work():
        finish_order.extend(engine.step())
    assert finish_order[0] == 0
    assert finish_order.index(2) < finish_order.index(1)


def test_engine_eos_early_exit(gemma_tiny):
    model, params = gemma_tiny
    # find the greedy first token, then use it as eos: generation stops at 1
    r = _req(0, 8, 16)
    engine = Engine(model, params, _policy())
    first = engine.run([r])[0][len(r.prompt)]
    r2 = Request(rid=1, prompt=r.prompt, max_new=16, eos_id=int(first))
    out = Engine(model, params, _policy()).run([r2])[1]
    assert len(out) == len(r.prompt) + 1
    # pages were freed on eviction
    assert engine.kv.allocator.num_free == engine.kv.allocator.num_pages - 1


def test_engine_moe_routing_smoke():
    """MoE decode rides the same paged path (drop-free tiny capacity)."""
    cfg = tiny_config("granite-moe-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, _policy(max_batch=2))
    reqs = [_req(i, 10, 4, vocab=cfg.vocab_size) for i in range(3)]
    outs = engine.run(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]), r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid


def test_engine_rejects_non_attention_families():
    cfg = tiny_config("mamba2-370m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        Engine(model, params, _policy())


def test_engine_quantized_weights_path(gemma_tiny):
    """quant_bits < 16 swaps in HAQ-quantized weights + dequant dot; the
    engine still serves (outputs differ from bf16 — only shape-checked)."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(quant_bits=8))
    r = _req(0, 12, 4)
    out = engine.run([r])[0]
    assert out.shape == (16,)
