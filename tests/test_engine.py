"""Continuous-batching engine: scheduler admission/eviction/backfill,
roofline admission policy, paged-pool bookkeeping, and greedy equivalence
with the sequential baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, tiny_config
from repro.core.hardware_model import V5E_EDGE, V5E_POD
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.serving.engine import (AdmissionPolicy, Engine, PageAllocator,
                                  Request, Scheduler, derive_policy)


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _req(rid, S, gen, *, vocab=512, arrival=0.0, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(2, vocab, S, dtype=np.int64)
                   .astype(np.int32), max_new=gen, arrival=arrival)


def _sched(max_batch=2, num_pages=9, page_size=16, max_len=64):
    return Scheduler(PageAllocator(num_pages, page_size), max_batch, max_len)


# -------------------------------------------------------------- scheduler --
def test_admission_respects_max_batch():
    s = _sched(max_batch=2, num_pages=100)
    for i in range(4):
        s.submit(_req(i, 8, 8))
    admitted = s.admit()
    assert [a.req.rid for a in admitted] == [0, 1]   # FIFO order
    assert s.num_active == 2 and s.num_queued == 2
    assert s.admit() == []                            # slots full


def test_admission_reserves_prompt_pages_only():
    """Lazy admission: a (20-prompt, 20-gen) request reserves only the
    2 pages its prompt (+ first decode slot) needs, not the 3-page
    worst case — so 3 requests fit where upfront reservation admits 2."""
    s = _sched(max_batch=4, num_pages=9, page_size=16)
    for i in range(3):
        s.submit(_req(i, 20, 20))                     # 21 tokens -> 2 pages
    admitted = s.admit()
    assert len(admitted) == 3
    assert all(len(a.pages) == 2 for a in admitted)
    assert s.allocator.num_free == 2
    assert all(0 not in a.pages for a in admitted)    # scratch never leased


def test_admission_respects_page_budget():
    # 5 usable pages (page 0 is scratch); each request needs 2 up front,
    # and admission keeps a one-page growth watermark once anything is in
    # flight — so the 3rd request (needing 2 + 1 headroom > 1 free) waits.
    s = _sched(max_batch=4, num_pages=6, page_size=16)
    for i in range(3):
        s.submit(_req(i, 20, 20))
    admitted = s.admit()
    assert len(admitted) == 2
    assert s.allocator.num_free == 1


def test_admission_upfront_reserves_worst_case():
    """reserve_upfront=True restores the legacy policy: every page of
    prompt+max_new reserved at admission (3 pages each here)."""
    s = Scheduler(PageAllocator(9, 16), 4, 64, reserve_upfront=True)
    for i in range(3):
        s.submit(_req(i, 20, 20))                     # 40 tokens -> 3 pages
    admitted = s.admit()
    assert len(admitted) == 2
    assert all(len(a.pages) == 3 for a in admitted)
    assert s.allocator.num_free == 2


def test_eviction_frees_pages_and_backfills():
    s = _sched(max_batch=2, num_pages=6, page_size=16)
    for i in range(3):
        s.submit(_req(i, 20, 20))
    first = s.admit()
    assert len(first) == 2                            # slots full
    assert s.admit() == []
    s.release(first[0])
    assert s.allocator.num_free == 3
    backfilled = s.admit()
    assert [a.req.rid for a in backfilled] == [2]
    assert backfilled[0].slot == first[0].slot        # slot reused


def test_page_allocator_rejects_double_free():
    """Regression: a page freed twice used to enter the free list twice and
    could be handed to two sequences."""
    a = PageAllocator(6, 16)
    pages = a.alloc(3)
    a.free(pages[:1])
    with pytest.raises(ValueError, match="double free"):
        a.free(pages)                    # pages[0] already back in the pool
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[1], pages[1]])     # duplicate within a single call
    # the guard kept state consistent: remaining pages still free cleanly
    a.free(pages[1:])
    assert a.num_free == 5 and a.num_allocated == 0
    with pytest.raises(ValueError):
        a.free([0])                      # scratch page is never leased


def test_growth_and_preemption_bookkeeping():
    """ensure_capacity grows page-by-page; exhaustion preempts the youngest,
    folding its generated tokens into a front-of-queue prompt extension."""
    s = _sched(max_batch=2, num_pages=5, page_size=16)
    s.submit(_req(0, 8, 40))
    s.submit(_req(1, 8, 40))
    a, b = s.admit()                     # 1 page each, 2 free
    for seq in (a, b):
        seq.generated.append(7)
        seq.pos = 8
    # walk a to position 47: needs 3 pages total, grabs the 2 free ones
    a.pos = 47
    assert s.ensure_capacity(a) and len(a.pages) == 3
    assert s.allocator.num_free == 0
    b.pos = 16                           # b crosses into block 1: no pages
    assert not s.ensure_capacity(b)
    # pages flow young -> old: the youngest is the victim, even when it is
    # the grower itself (b here, so b yields rather than stalling a)
    victim = s.youngest_active()
    assert victim is b
    s.preempt(victim)
    assert s.num_preempted == 1
    assert s.num_active == 1 and s.allocator.num_free == 1
    # b went back to the FIFO front with its generated token folded in
    req = s.queue[0]
    assert req.rid == 1 and len(req.prompt) == 9 and req.max_new == 39
    # with only a active, a itself is the youngest (the engine treats
    # "victim is grower and alone" as a pool-sizing error)
    assert s.youngest_active() is a


def test_admission_respects_arrival_times():
    s = _sched(max_batch=4, num_pages=100)
    s.submit(_req(0, 8, 8, arrival=0.0))
    s.submit(_req(1, 8, 8, arrival=5.0))
    assert [a.req.rid for a in s.admit(now=1.0)] == [0]
    assert [a.req.rid for a in s.admit(now=6.0)] == [1]


def test_submit_rejects_oversized_request():
    s = _sched(max_len=32)
    with pytest.raises(ValueError):
        s.submit(_req(0, 30, 10))


# ------------------------------------------------------- admission policy --
def test_admission_policy_haq_quant_on_edge():
    """8B params at bf16 (~16 GiB) can't fit the edge chip's HBM next to a
    4k sequence -> policy demands the HAQ int8 policy; the pod doesn't."""
    cfg = get_config("granite-3-8b")
    edge = derive_policy(cfg, V5E_EDGE, max_model_len=4096)
    pod = derive_policy(cfg, V5E_POD, max_model_len=4096)
    assert edge.quant_bits == 8
    assert pod.quant_bits == 16
    assert pod.max_batch > edge.max_batch
    assert pod.prefill_chunk >= edge.prefill_chunk
    assert edge.est_decode_s <= edge.decode_slo_s


def test_admission_policy_pages_fit_hbm():
    cfg = get_config("gemma2-2b")
    pol = derive_policy(cfg, V5E_EDGE, max_model_len=4096)
    from repro.serving.engine.admission import kv_bytes_per_token
    kv_bytes = (pol.num_pages - 1) * pol.page_size * kv_bytes_per_token(cfg)
    assert kv_bytes + cfg.param_count() * 2 * pol.quant_bits / 16 \
        <= V5E_EDGE.hbm_bytes
    # the pool must always hold >= 1 full-length sequence, else a legal
    # request could wait on page allocation forever
    assert pol.num_pages - 1 >= pol.pages_per_seq


# ----------------------------------------------------------------- engine --
@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_matches_sequential_greedy(gemma_tiny):
    """Mixed-length continuous batching is token-identical to serving each
    request alone through the dense sequential baseline."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy())
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(7):
        S = int(rng.integers(4, 44))     # spans the local window (32)
        gen = int(rng.integers(2, 16))
        reqs.append(Request(rid=i, prompt=rng.integers(
            2, model.cfg.vocab_size, S).astype(np.int32), max_new=gen))
    outs = engine.run(reqs)
    assert engine.stats["admitted"] == len(reqs)
    # batched: strictly fewer decode ticks than total decoded tokens
    assert engine.stats["decode_ticks"] < engine.stats["decode_tokens"]
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]), r.max_new)[0])
        got = outs[r.rid]
        assert got.shape == (len(r.prompt) + r.max_new,)
        assert np.array_equal(want, got), r.rid


def test_engine_backfills_mid_flight(gemma_tiny):
    """With 2 slots and 3 requests, the short request finishes first and the
    queued one backfills while the long one is still decoding."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2))
    reqs = [_req(0, 8, 2), _req(1, 8, 24), _req(2, 8, 2)]
    for r in reqs:
        engine.submit(r)
    finish_order = []
    while engine.scheduler.has_work():
        finish_order.extend(engine.step())
    assert finish_order[0] == 0
    assert finish_order.index(2) < finish_order.index(1)


def test_engine_eos_early_exit(gemma_tiny):
    model, params = gemma_tiny
    # find the greedy first token, then use it as eos: generation stops at 1
    r = _req(0, 8, 16)
    engine = Engine(model, params, _policy())
    first = engine.run([r])[0][len(r.prompt)]
    r2 = Request(rid=1, prompt=r.prompt, max_new=16, eos_id=int(first))
    out = Engine(model, params, _policy()).run([r2])[1]
    assert len(out) == len(r.prompt) + 1
    # pages were freed on eviction
    assert engine.kv.allocator.num_free == engine.kv.allocator.num_pages - 1


def test_engine_moe_routing_smoke():
    """MoE decode rides the same paged path (drop-free tiny capacity)."""
    cfg = tiny_config("granite-moe-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, _policy(max_batch=2))
    reqs = [_req(i, 10, 4, vocab=cfg.vocab_size) for i in range(3)]
    outs = engine.run(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]), r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid


def test_engine_rejects_non_attention_families():
    cfg = tiny_config("mamba2-370m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        Engine(model, params, _policy())


def test_engine_quantized_weights_path(gemma_tiny):
    """quant_bits < 16 swaps in HAQ-quantized weights + dequant dot; the
    engine still serves (outputs differ from bf16 — only shape-checked)."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(quant_bits=8))
    r = _req(0, 12, 4)
    out = engine.run([r])[0]
    assert out.shape == (16,)


def test_engine_pallas_kernel_path(gemma_tiny):
    """The Pallas paged-attention kernel (interpret mode on CPU) serves the
    same trace the block-walk path does: outputs token-identical to the
    sequential baseline. Kept tiny — interpret mode runs the kernel body
    per grid program in Python."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2),
                    paged_kernel="pallas")
    reqs = [_req(0, 8, 4), _req(1, 11, 3)]
    outs = engine.run(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid


def test_engine_preemption_roundtrip_exact(gemma_tiny):
    """A pool too small for both sequences' full lifetimes forces at least
    one preemption (free pages + requeue as prompt-extension + re-prefill);
    greedy outputs stay token-identical to the sequential baseline."""
    model, params = gemma_tiny
    # pages_per_seq=4 (64/16); 6 usable pages; both requests grow to 4
    # pages (12 + 44 = 56 tokens), so one must be preempted mid-flight.
    engine = Engine(model, params, _policy(max_batch=2, num_pages=7))
    reqs = [_req(0, 12, 44), _req(1, 12, 44)]
    outs = engine.run(reqs)
    assert engine.stats["preemptions"] >= 1
    assert engine.stats["grown_pages"] >= 3      # lazy growth really ran
    assert engine.scheduler.num_preempted == engine.stats["preemptions"]
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   44)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid
    # all pages returned after drain
    assert engine.kv.allocator.num_allocated == 0


def test_engine_lazy_beats_upfront_admission(gemma_tiny):
    """With the same constrained pool, lazy allocation admits both requests
    at once where upfront reservation serializes them."""
    model, params = gemma_tiny
    reqs = [_req(0, 12, 44), _req(1, 12, 44)]
    ticks = {}
    for upfront in (True, False):
        engine = Engine(model, params, _policy(max_batch=2, num_pages=7),
                        reserve_upfront=upfront)
        outs = engine.run([_req(i, 12, 44) for i in range(2)])
        ticks[upfront] = engine.stats["decode_ticks"]
        for r in reqs:
            want = np.asarray(generate(model, params,
                                       jnp.asarray(r.prompt[None]), 44)[0])
            assert np.array_equal(want, outs[r.rid]), (upfront, r.rid)
    # upfront: 4+4 pages never fit 6 -> strictly serial -> ~2x the ticks
    assert ticks[False] < ticks[True]


def _iter_avals(jaxpr):
    from jax.core import Jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            subs = p if isinstance(p, (list, tuple)) else [p]
            for s in subs:
                inner = getattr(s, "jaxpr", None)
                if isinstance(s, Jaxpr):
                    yield from _iter_avals(s)
                elif isinstance(inner, Jaxpr):
                    yield from _iter_avals(inner)


def test_paged_decode_never_builds_dense_kv(gemma_tiny):
    """Acceptance: the jitted decode step contains no chronological
    (B, max_pages*page, K, hd) dense KV intermediate — neither flat nor in
    its pre-reshape (B, max_pages, page, K, hd) form."""
    model, params = gemma_tiny
    pol = _policy()
    B, maxp, page = pol.max_batch, pol.pages_per_seq, pol.page_size
    K, hd = model.cfg.num_kv_heads, model.cfg.resolved_head_dim
    pool = model.init_pool(9, page)
    pt = jnp.zeros((B, maxp), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: model.decode_step_paged(*a))(params, pool, pt, tok, pos)
    banned = {(B, maxp * page, K, hd), (B, maxp, page, K, hd)}
    dense = [a for a in _iter_avals(jaxpr.jaxpr)
             if getattr(a, "shape", None) in banned]
    assert not dense, dense
    # positive control: the same scan flags the dense-gather oracle
    from repro.kernels.ref import paged_attention_dense_ref
    q = jnp.zeros((B, model.cfg.num_heads, hd), jnp.bfloat16)
    pk = jax.tree.leaves(pool)[0][0]          # (P, page, K, hd)
    jx = jax.make_jaxpr(
        lambda *a: paged_attention_dense_ref(*a))(q, pk, pk, pt, pos)
    hits = [a for a in _iter_avals(jx.jaxpr)
            if getattr(a, "shape", None) in banned]
    assert hits, "aval scan lost its teeth"


def test_jit_lru_caches_are_bounded(gemma_tiny):
    """Per-shape jit caches (pool writer, prefill buckets) evict LRU past
    their cap instead of growing with every new bucket shape."""
    from repro.serving.engine.pool import JitLRU
    lru = JitLRU(cap=2)
    calls = []
    for key in ["a", "b", "a", "c", "b"]:
        lru.get(key, lambda k=key: calls.append(k) or k)
    # "a" was fresh when "c" evicted "b"; "b" recompiles
    assert calls == ["a", "b", "c", "b"]
    assert len(lru) == 2 and lru.hits == 1 and lru.misses == 4

    model, params = gemma_tiny
    engine = Engine(model, params, _policy(prefill_chunk=4),
                    chunked_prefill=False)
    # 5 distinct prompt lengths -> 5 distinct padding buckets
    engine.run([_req(i, 4 * (i + 1), 2) for i in range(5)])
    assert len(engine._prefill_jits) <= Engine.PREFILL_JIT_CAP
    assert len(engine.kv._write_jit) <= engine.kv.WRITE_JIT_CAP
    assert engine._prefill_jits.misses == 5

    # the chunked engine needs no padding-bucket jits at all: every
    # prompt length rides the single fixed-shape chunk closure
    engine = Engine(model, params, _policy(prefill_chunk=4))
    engine.run([_req(i, 4 * (i + 1), 2) for i in range(5)])
    assert len(engine._prefill_jits) == 0
    assert len(engine.kv._write_jit) == 0


@pytest.mark.slow
def test_engine_smoke_long_trace(gemma_tiny):
    """CI smoke: a 12-request trace with long tails on a constrained pool —
    exercises admission, growth, preemption, backfill, and eviction in one
    run and checks every output against the sequential baseline."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=3, num_pages=9))
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(12):
        S = int(rng.integers(4, 16))
        gen = int(rng.integers(4, 64 - S))
        reqs.append(Request(rid=i, prompt=rng.integers(
            2, model.cfg.vocab_size, S).astype(np.int32), max_new=gen))
    outs = engine.run(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid
    assert engine.kv.allocator.num_allocated == 0
