"""HAQ core (§4): site enumeration, budget back-off, policy evaluation,
hardware-specific policies (the paper's central claim)."""
import jax

from repro.configs import get_config, tiny_config
from repro.core import haq
from repro.core import quantization as q
from repro.core.hardware_model import V5E_EDGE
from repro.models.api import build_model

from conftest import tiny_batch


def test_site_enumeration_families():
    for arch, expect in [("granite-3-8b", {"attn_q", "ffn_in", "ffn_gate"}),
                         ("granite-moe-3b-a800m", {"moe_in", "moe_out"}),
                         ("mamba2-370m", {"ssm_in", "ssm_out"})]:
        sites = {s.name for s in haq.enumerate_sites(get_config(arch), 1, 128)}
        assert expect <= sites, (arch, sites)


def test_budget_backoff_terminates_and_fits():
    cfg = get_config("granite-3-8b")
    sites = haq.enumerate_sites(cfg, batch=1, seq=1, decode=True)
    wa = [(8, 8)] * len(sites)
    base = haq.resource(sites, wa, V5E_EDGE, "latency")
    out = haq.enforce_budget(sites, wa, V5E_EDGE, 0.5 * base, "latency")
    assert haq.resource(sites, out, V5E_EDGE, "latency") <= 0.5 * base


def test_decode_is_memory_bound_prefill_compute_bound():
    """Roofline sanity behind the paper's edge/cloud policy difference."""
    cfg = get_config("granite-3-8b")
    dec = haq.enumerate_sites(cfg, batch=1, seq=1, decode=True)[0]
    pre = haq.enumerate_sites(cfg, batch=8, seq=4096)[0]
    hw = V5E_EDGE
    # decode: memory term dominates -> quantizing weights helps ~linearly
    t8 = dec.latency(hw, 8, 16)
    t4 = dec.latency(hw, 4, 16)
    assert t4 < 0.7 * t8
    # prefill: compute-bound -> weight bits below 8 give ~no latency win
    p8 = pre.latency(hw, 8, 16)
    p4 = pre.latency(hw, 4, 16)
    assert p4 > 0.9 * p8


def test_policy_eval_with_model():
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    base = float(model.loss(params, batch))

    def eval_policy(policy):
        dot = q.make_quant_dot({k: v for k, v in policy.items()})
        return model.loss(params, batch, dot=dot)

    l16 = float(eval_policy({s.name: (16, 16) for s in
                             haq.enumerate_sites(cfg, 2, 32)}))
    l2 = float(eval_policy({s.name: (2, 4) for s in
                            haq.enumerate_sites(cfg, 2, 32)}))
    # 16-bit policy is a no-op up to einsum accumulation-dtype defaults;
    # 2-bit everywhere perturbs the function far more (on an untrained
    # subject the loss can move either way; trained-subject quality ordering
    # is benchmarks/table6's job)
    assert abs(l16 - base) < 1e-3
    assert abs(l2 - base) > 10 * abs(l16 - base)


def test_haq_search_small():
    """End-to-end mini search on a memory-bound (decode) site set: returns a
    budget-feasible policy whose loss beats the all-minimum-bits policy."""
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    # memory-bound decode-shaped sites: quantization really buys latency here
    sites = haq.enumerate_sites(cfg, 2, 32, decode=True)

    def eval_policy(policy):
        return float(model.loss(params, batch,
                                dot=q.make_quant_dot(policy)))

    res = haq.search(cfg, sites, eval_policy,
                     haq.HAQConfig(episodes=8, budget_frac=0.7),
                     hw=V5E_EDGE)
    floor = haq.resource(sites, [(min(haq.W_BITS), min(haq.A_BITS))]
                         * len(sites), V5E_EDGE, "latency")
    assert res["best"]["resource"] <= res["best"]["budget"] + 1e-12 \
        or abs(res["best"]["resource"] - floor) < 1e-12
    # quality sanity: the chosen policy does not blow up the loss (on an
    # UNTRAINED tiny subject quantization noise is ~flat, so comparisons
    # between low-bit policies are meaningless — trained-subject quality
    # ordering is covered in benchmarks/table6)
    assert res["best"]["loss"] <= res["base_loss"] + 0.5
