"""Serving-stack autotuner (serving/autotune): config-space encoding and
constraint properties, the calibrated objective and its raw-roofline
fallback, ScaleLookup resolution, search determinism, config JSON I/O,
and the end-to-end tune loop on the tiny engine.

Property tests run under hypothesis when it is installed and fall back
to a seeded fuzz sweep otherwise (same idiom as test_distribution.py —
the -ra summary says which ran)."""

import dataclasses
import json
import logging
import math

import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.hardware_model import V5E_EDGE
from repro.serving.autotune import (
    ConfigSpace,
    Objective,
    config_record,
    evolutionary_search,
    load_serving_config,
    save_serving_config,
    search_serving_config,
    spearman,
)
from repro.serving.engine.admission import (
    RooflinePredictor,
    derive_policy,
    kv_bytes_per_token,
)
from repro.serving.telemetry import ScaleLookup, calibrate
from repro.serving.telemetry.events import TickEvent

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ARCH = "gemma2-2b"
MAX_LEN = 96


@pytest.fixture(scope="module")
def space():
    cfg = tiny_config(ARCH)
    return ConfigSpace(cfg, V5E_EDGE, max_model_len=MAX_LEN,
                       max_devices=8, max_batch_cap=8)


# ----------------------------------------------------- space properties --
def check_roundtrip(space, idxs):
    """encode/decode and indices/from_indices are exact inverses for
    every point of the space (the DDPG agent lives in the hypercube, so
    a lossy round-trip would silently search a different space)."""
    c = space.from_indices(idxs)
    assert space.from_indices(space.indices(c)) == c
    assert space.decode(space.encode(c)) == c
    vec = space.encode(c)
    assert vec.shape == (space.num_dims,)
    assert np.all((0.0 <= vec) & (vec <= 1.0))


def check_candidate_constraints(space, idxs):
    """Every admissible candidate lowers to a policy that respects the
    structural constraints: chunk <= bucket, mesh divides kv_heads, the
    batch cap binds, and the derived pool fits the HBM budget."""
    c = space.from_indices(idxs)
    # structural invariants hold for ALL sampled points, by construction
    assert 0 < c.page_size <= space.max_model_len
    assert 0 < c.prefill_chunk <= space.max_model_len
    assert space.cfg.num_kv_heads % c.mesh_model == 0
    assert c.mesh_model <= space.max_devices
    assert 0.0 < c.expected_occupancy <= 1.0
    assert 1 <= c.max_batch_cap <= space.max_batch_cap
    if space.violations(c):
        return
    policy = space.to_policy(c)
    assert 1 <= policy.max_batch <= c.max_batch_cap
    assert policy.prefill_chunk == c.prefill_chunk
    assert policy.page_size == c.page_size
    assert policy.mesh_model == c.mesh_model
    # HBM feasibility: the per-shard pool never exceeds the 0.9-util HBM
    # budget plus the one-sequence floor and page-rounding slack
    # derive_policy documents
    per_tok = kv_bytes_per_token(space.cfg, policy.kv_bits)
    page_bytes = policy.page_size * per_tok / policy.mesh_model
    pool_bytes = policy.num_pages * page_bytes
    hbm = space.hw.hbm_bytes * space.hw.chips * 0.9
    one_seq = per_tok * space.max_model_len / policy.mesh_model
    assert pool_bytes <= hbm + one_seq + 2 * page_bytes
    assert policy.num_pages > -(-space.max_model_len // policy.page_size)


if HAVE_HYPOTHESIS:
    @settings(max_examples=150, deadline=None)
    @given(data=st.data())
    def test_space_roundtrip(data, space):
        idxs = [data.draw(st.integers(0, len(ch) - 1), label=name)
                for name, ch in space.dims]
        check_roundtrip(space, idxs)

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_space_constraints(data, space):
        idxs = [data.draw(st.integers(0, len(ch) - 1), label=name)
                for name, ch in space.dims]
        check_candidate_constraints(space, idxs)
else:
    def test_space_roundtrip(space):
        rng = np.random.default_rng(0)
        for _ in range(150):
            check_roundtrip(
                space, [int(rng.integers(len(ch))) for _, ch in space.dims])

    def test_space_constraints(space):
        rng = np.random.default_rng(1)
        for _ in range(60):
            check_candidate_constraints(
                space, [int(rng.integers(len(ch))) for _, ch in space.dims])


def test_space_default_is_admissible(space):
    d = space.default()
    assert space.violations(d) == ()
    assert d.page_size == 16 and d.kv_policy == "fp16"
    assert d.mesh_model == 1 and d.max_batch_cap == space.max_batch_cap


def test_space_rejects_out_of_space_values(space):
    bad = dataclasses.replace(space.default(), page_size=7)
    assert any("page_size" in v for v in space.violations(bad))
    with pytest.raises(ValueError, match="page_size"):
        space.indices(bad)
    with pytest.raises(ValueError, match="unknown kv"):
        space.kv_bits_for("int3")


def test_space_mesh_dim_respects_devices_and_heads():
    cfg = tiny_config(ARCH)
    solo = ConfigSpace(cfg, V5E_EDGE, max_model_len=MAX_LEN, max_devices=1)
    assert dict(solo.dims)["mesh_model"] == (1,)
    wide = ConfigSpace(cfg, V5E_EDGE, max_model_len=MAX_LEN, max_devices=16)
    for m in dict(wide.dims)["mesh_model"]:
        assert cfg.num_kv_heads % m == 0


# ------------------------------------------------------------ ScaleLookup --
def test_scale_lookup_resolution_order():
    lk = ScaleLookup(by_shape={("decode", 8, 1): 700.0},
                     by_kind={"decode": 900.0, "chunk": 40.0})
    assert lk.scale("decode", 8, 1) == 700.0     # exact shape first
    assert lk.scale("decode", 4, 1) == 900.0     # kind aggregate next
    assert lk.scale("chunk") == 40.0             # shape optional
    assert lk.scale("prefill", 1, 64) is None    # unknown kind -> None
    assert lk.kinds() == ("chunk", "decode")
    back = ScaleLookup.from_dict(lk.as_dict())
    assert back == lk


def test_calibration_report_exports_scale_lookup():
    def tick(kind, batch, q_len, measured, predicted):
        return TickEvent(kind=kind, step=0, t_start=0.0,
                         measured_s=measured, predicted_s=predicted,
                         batch=batch, padded_batch=batch, q_len=q_len,
                         tokens=batch)

    ticks = [tick("decode", 8, 1, 4e-3, 1e-3) for _ in range(4)]
    # unknown-hw group: predicted 0.0 -> scale None -> dropped from the
    # lookup rather than exported as a bogus factor
    ticks += [tick("chunk", 1, 32, 2e-3, 0.0) for _ in range(3)]
    lk = calibrate(ticks).scale_lookup()
    assert lk.scale("decode", 8, 1) == pytest.approx(4.0)
    assert lk.scale("chunk", 1, 32) is None
    assert "chunk" not in lk.kinds()


def test_roofline_predictor_applies_scales():
    cfg = tiny_config(ARCH)
    policy = derive_policy(cfg, V5E_EDGE, max_model_len=MAX_LEN)
    raw = RooflinePredictor(cfg, policy)
    scaled = RooflinePredictor(
        cfg, policy, scales=ScaleLookup(by_kind={"decode": 3.0}))
    got = raw("decode", 4, 1)
    assert got > 0.0
    assert scaled("decode", 4, 1) == pytest.approx(3.0 * got)
    # kinds without a scale pass through unchanged
    assert scaled("chunk", 1, 32) == pytest.approx(raw("chunk", 1, 32))


def test_roofline_predictor_unknown_hw_stays_zero():
    cfg = tiny_config(ARCH)
    policy = derive_policy(cfg, V5E_EDGE, max_model_len=MAX_LEN)
    policy = dataclasses.replace(policy, hw_name="made-up-hw")
    pred = RooflinePredictor(
        cfg, policy, scales=ScaleLookup(by_kind={"decode": 3.0}))
    # no roofline for an unknown target: raw is 0.0 and scales are NOT
    # applied to it (0.0 * scale would fake a prediction of 0)
    assert pred.raw("decode", 4, 1) == 0.0
    assert pred("decode", 4, 1) == 0.0


# -------------------------------------------------------------- objective --
def test_objective_falls_back_to_raw_roofline(space, caplog):
    """The unknown-hw_name fix: no calibration -> RAW roofline with a
    logged warning (once per kind), never zero scores."""
    for scales in (None, ScaleLookup()):
        obj = Objective(space, scales=scales)
        with caplog.at_level(logging.WARNING,
                             logger="repro.serving.autotune.objective"):
            caplog.clear()
            sc = obj(space.default())
            obj(dataclasses.replace(space.default(), page_size=32))
        assert sc.admissible and not sc.calibrated
        assert math.isfinite(sc.score) and sc.score > 0.0
        assert sc.pred_decode_tick_s > 0.0 and sc.pred_ttft_s > 0.0
        warned = [r for r in caplog.records if "RAW roofline" in r.message]
        assert len(warned) == 2            # once per kind, not per call
        assert {("decode" in r.message, "chunk" in r.message)
                for r in warned} == {(True, False), (False, True)}


def test_objective_applies_calibration_scales(space):
    raw = Objective(space, scales=None)(space.default())
    cal = Objective(
        space,
        scales=ScaleLookup(by_kind={"decode": 2.0, "chunk": 5.0}),
    )(space.default())
    assert cal.calibrated and not raw.calibrated
    assert cal.pred_decode_tick_s == pytest.approx(
        2.0 * raw.pred_decode_tick_s)
    assert cal.pred_ttft_s == pytest.approx(5.0 * raw.pred_ttft_s)
    assert cal.score == pytest.approx(raw.score / 2.0)


def test_objective_scores_inadmissible_neg_inf(space):
    bad = dataclasses.replace(space.default(), prefill_chunk=7)
    sc = Objective(space)(bad)
    assert not sc.admissible and sc.score == float("-inf")
    assert sc.violations
    # memoized: the same object comes back
    obj = Objective(space)
    assert obj(bad) is obj(bad)


def test_objective_ttft_slo_discounts_slow_prefill(space):
    c = space.default()
    free = Objective(space)(c)
    tight = Objective(space, ttft_slo_s=1e-9)(c)
    assert tight.score < free.score
    assert tight.pred_decode_tok_s == pytest.approx(free.pred_decode_tok_s)


# ----------------------------------------------------------------- search --
def test_evolutionary_search_deterministic_and_budgeted(space):
    obj = Objective(space)
    a = evolutionary_search(space, obj, budget=16, seed=3)
    b = evolutionary_search(space, Objective(space), budget=16, seed=3)
    assert [s.config for s in a] == [s.config for s in b]
    assert [s.score for s in a] == [s.score for s in b]
    assert 0 < len(a) <= 16
    # the hand-picked default is always in the evaluated set, so the
    # best search result can never score below it
    assert space.default() in {s.config for s in a}
    best = max(s.score for s in a if s.admissible)
    assert best >= obj(space.default()).score


@pytest.mark.search
def test_search_smoke_deterministic(space):
    """CI smoke: both searchers (the DDPG episodes included) are
    deterministic under a fixed seed and respect the budget."""
    r1 = search_serving_config(space, Objective(space), budget=8, seed=0)
    r2 = search_serving_config(space, Objective(space), budget=8, seed=0)
    assert [s.config for s in r1.ranked] == [s.config for s in r2.ranked]
    assert r1.evaluated >= 1 and r1.admissible >= 1
    assert r1.best is not None and r1.best.admissible
    assert r1.method == "both" and r1.budget == 8
    other = search_serving_config(space, Objective(space), budget=8,
                                  seed=1, method="evolution")
    assert other.method == "evolution"
    with pytest.raises(ValueError, match="unknown search method"):
        search_serving_config(space, Objective(space), method="anneal")


# -------------------------------------------------------------- config I/O --
def test_serving_config_json_roundtrip(space, tmp_path):
    c = space.default()
    rec = config_record(space, c, budget=8, note="test")
    path = tmp_path / "serving.json"
    save_serving_config(str(path), rec)
    back, record = load_serving_config(str(path))
    assert back == c
    assert record["hw"] == V5E_EDGE.name
    assert record["arch"] == space.cfg.name
    assert record["max_model_len"] == MAX_LEN
    assert record["provenance"]["budget"] == 8
    # records are plain JSON all the way down
    json.dumps(rec)

    bad = dict(rec, schema=999)
    path.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="schema"):
        load_serving_config(str(path))


# ---------------------------------------------------------------- spearman --
def test_spearman():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [9, 7, 5, 3]) == pytest.approx(-1.0)
    assert spearman([1, 2, 3, 4], [1, 3, 2, 4]) == pytest.approx(0.8)
    assert spearman([1, 2], [2, 1]) is None          # too few points
    assert spearman([1, 1, 1], [1, 2, 3]) is None    # constant side
    got = spearman([1, 2, 2, 3], [1, 2, 3, 4])       # ties: average ranks
    assert got is not None and 0.9 < got <= 1.0


# ------------------------------------------------------------- end-to-end --
@pytest.mark.slow
@pytest.mark.search
def test_autotune_end_to_end_tiny_engine():
    """Full loop on the real tiny engine: calibrate, search, validate,
    and the acceptance floor CI gates on — the winner's measured decode
    tok/s never falls below the hand-picked default's."""
    import jax

    from repro.models.api import build_model
    from repro.serving.autotune import autotune_serving_config
    from repro.serving.engine import Request

    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    space = ConfigSpace(cfg, V5E_EDGE, max_model_len=48,
                        max_devices=jax.device_count(), max_batch_cap=4,
                        param_bytes=model.param_bytes())
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 24)
                    .astype(np.int32),
                    max_new=8) for i in range(3)]
    tune = autotune_serving_config(model, params, space, reqs,
                                   budget=10, top_k=2, seed=0)
    assert tune.searched_vs_default >= 0.95
    assert tune.winner.decode_tok_s >= tune.default.decode_tok_s * 0.95
    assert tune.search.evaluated >= 1 and tune.search.admissible >= 1
    assert tune.validated[0].scored.config == space.default()
    assert tune.scales.kinds()            # the warmup really calibrated
    assert all(m.scored.calibrated for m in tune.validated)
    rec = tune.record(space)
    assert rec["knobs"] == tune.winner.scored.config.as_dict()
    assert rec["provenance"]["searched_vs_default"] == pytest.approx(
        tune.searched_vs_default)
