"""SPMD serving engine (serving/engine/sharded.py): token-exactness vs the
1-device engine across pools/chunking/preemption, per-device pool layout,
mesh-aware admission sizing, and the no-dense-KV jaxpr contract.

Multi-device cases need forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
``multi-device`` job) and skip elsewhere; the admission/roofline cases are
pure host math and run everywhere."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.hardware_model import V5E_EDGE, allreduce_cost
from repro.launch.mesh import make_serving_mesh
from repro.models.api import build_model
from repro.serving.engine import (AdmissionPolicy, Engine, Request,
                                  derive_policy)
from repro.serving.engine.admission import step_latency

NDEV = jax.device_count()
needs2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs4 = pytest.mark.skipif(
    NDEV < 4, reason="needs >=4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _reqs(cfg, n=6, seed=0, gen_hi=16):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        S = int(rng.integers(4, 44))        # spans the local window (32)
        gen = int(rng.integers(2, gen_hi))
        out.append(Request(rid=i, prompt=rng.integers(
            2, cfg.vocab_size, S).astype(np.int32), max_new=gen))
    return out


@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")          # GQA (H=4, K=2), local+global
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _assert_identical(model, params, pol, reqs, mesh, **engine_kw):
    base = Engine(model, params, pol, **engine_kw)
    want = base.run(reqs)
    sharded = Engine(model, params, pol, mesh=mesh, **engine_kw)
    got = sharded.run(reqs)
    for r in reqs:
        assert np.array_equal(want[r.rid], got[r.rid]), r.rid
    return base, sharded


# ------------------------------------------------------- token exactness --
@needs2
@pytest.mark.parametrize("kv_bits", [None, (8,), (4, 8)],
                         ids=["fp", "int8", "haq-mixed"])
def test_sharded_matches_unsharded(gemma_tiny, kv_bits):
    """Greedy outputs on a model=2 mesh are bit-identical to the 1-device
    engine for the fp, int8, and HAQ-mixed (int4 local / int8 global)
    pools — chunked prefill included (prompts up to 43 vs chunk 16)."""
    model, params = gemma_tiny
    pol = _policy(kv_bits=kv_bits)
    _assert_identical(model, params, pol, _reqs(model.cfg),
                      make_serving_mesh(model=2))


@needs4
def test_sharded_data_axis(gemma_tiny):
    """The data axis is at-rest param FSDP: outputs unchanged on a
    model=2 x data=2 mesh."""
    model, params = gemma_tiny
    _assert_identical(model, params, _policy(), _reqs(model.cfg),
                      make_serving_mesh(model=2, data=2))


@needs2
def test_sharded_preemption_roundtrip_exact(gemma_tiny):
    """Forced preemption (pool smaller than two full lifetimes) replays
    identically on the sharded engine: same preemption count, same
    tokens, all pages returned on both."""
    model, params = gemma_tiny
    pol = _policy(max_batch=2, num_pages=7)
    reqs = [Request(rid=i, prompt=np.full(12, 7 + i, np.int32), max_new=44)
            for i in range(2)]
    base, sharded = _assert_identical(model, params, pol, reqs,
                                      make_serving_mesh(model=2))
    assert base.stats["preemptions"] >= 1
    assert sharded.stats["preemptions"] == base.stats["preemptions"]
    assert sharded.kv.allocator.num_allocated == 0


@needs2
def test_sharded_whole_prompt_prefill(gemma_tiny):
    """chunked_prefill=False rides the sharded bucketed prefill + the
    shard_map'd pool span-writer; outputs stay bit-identical."""
    model, params = gemma_tiny
    _assert_identical(model, params, _policy(), _reqs(model.cfg),
                      make_serving_mesh(model=2), chunked_prefill=False)


@needs2
def test_sharded_moe_smoke():
    """MoE decode under a mesh (expert weights gathered at use)."""
    cfg = tiny_config("granite-moe-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _assert_identical(model, params, _policy(max_batch=2),
                      _reqs(cfg, n=3, seed=1), make_serving_mesh(model=2))


# ------------------------------------------------------- device layout ----
@needs2
def test_pool_is_sharded_on_kv_heads(gemma_tiny):
    """Acceptance: every pool leaf (codes AND quant scale tiles) stores a
    1/N kv-head slice per device — per-device pool bytes really drop Nx."""
    model, params = gemma_tiny
    for kv_bits in (None, (8,)):
        pol = _policy(kv_bits=kv_bits)
        eng = Engine(model, params, pol, mesh=make_serving_mesh(model=2))
        K = model.cfg.num_kv_heads
        for leaf in jax.tree.leaves(eng.kv.pool):
            local = leaf.sharding.shard_shape(leaf.shape)
            assert local[3] == K // 2, (leaf.shape, local)
        # replicated decode inputs, sharded params at rest: param bytes per
        # device strictly below the full footprint
        full = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(eng.params))
        local = sum(int(np.prod(x.sharding.shard_shape(x.shape)))
                    * x.dtype.itemsize for x in jax.tree.leaves(eng.params))
        assert local < full


@needs2
def test_sharded_decode_never_builds_dense_kv(gemma_tiny):
    """The sharded decode jaxpr never materializes a chronological dense KV
    view — neither at the full kv-head count nor at the local slice."""
    from test_engine import _iter_avals

    model, params = gemma_tiny
    pol = _policy()
    mesh = make_serving_mesh(model=2)
    eng = Engine(model, params, pol, mesh=mesh)
    B, maxp, page = pol.max_batch, pol.pages_per_seq, pol.page_size
    K, hd = model.cfg.num_kv_heads, model.cfg.resolved_head_dim
    pt = jnp.zeros((B, maxp), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: eng._decode(*a))(eng.params, eng.kv.pool, pt, tok, pos)
    banned = set()
    for k in (K, K // 2):
        banned |= {(B, maxp * page, k, hd), (B, maxp, page, k, hd)}
    dense = [a for a in _iter_avals(jaxpr.jaxpr)
             if getattr(a, "shape", None) in banned]
    assert not dense, dense


def test_mesh_validation_errors(gemma_tiny):
    """K=2 does not divide model=4; the engine refuses instead of silently
    regrouping GQA (page slots must stay whole for bit-exactness)."""
    from repro.serving.engine.sharded import validate_mesh

    model, params = gemma_tiny

    class FakeMesh:
        def __init__(self, **axes):
            self.shape = axes

    with pytest.raises(ValueError, match="kv heads"):
        validate_mesh(model.cfg, FakeMesh(data=1, model=4))
    with pytest.raises(ValueError, match="data/model"):
        validate_mesh(model.cfg, FakeMesh(rows=2))
    validate_mesh(model.cfg, FakeMesh(data=4, model=2))   # fine


# ------------------------------------------- mesh-aware admission sizing --
def test_policy_pool_capacity_scales_with_model_axis():
    """Acceptance: pool capacity per device scales >= 1.9x from 1 -> 2
    model shards (per-device page bytes halve; weights also spread)."""
    cfg = tiny_config("gemma2-2b")
    base = derive_policy(cfg, V5E_EDGE, max_model_len=64)
    two = derive_policy(cfg, V5E_EDGE, max_model_len=64, mesh_model=2)
    assert two.num_pages >= 1.9 * base.num_pages
    assert two.mesh_model == 2 and two.mesh_data == 1
    # expected-footprint resident-sequence capacity rises with it
    assert two.max_batch >= base.max_batch
    # data axis alone replicates the pool: capacity moves only via the
    # (spread) weight share, never ~2x
    dp = derive_policy(cfg, V5E_EDGE, max_model_len=64, mesh_data=2)
    assert dp.num_pages < 1.5 * base.num_pages
    # defaults reproduce the single-device policy exactly
    one = derive_policy(cfg, V5E_EDGE, max_model_len=64,
                        mesh_model=1, mesh_data=1)
    assert one == base


def test_step_latency_mesh_model_prices_collectives():
    """The mesh-aware roofline is faithful to the gather-at-use design:
    only output-dim-sharded work splits N ways, so with free ICI the tick
    shrinks but never to t1/N; real ICI only ever adds (activation
    all-reduces + weight all-gathers), and the whole-on-every-device part
    keeps t2 above perfect scaling."""
    import dataclasses as dc

    cfg = tiny_config("gemma2-2b")
    t1 = step_latency(cfg, 8, 1, 64, V5E_EDGE)
    t2 = step_latency(cfg, 8, 1, 64, V5E_EDGE, mesh_model=2)
    free_ici = dc.replace(V5E_EDGE, ici_bw=1e18)
    t2_free = step_latency(cfg, 8, 1, 64, free_ici, mesh_model=2)
    assert t1 / 2.0 < t2_free < t1          # split helps, whole part stays
    assert t2 >= t2_free                    # collectives only ever add
    ar = float(allreduce_cost(8, cfg.d_model, 2).latency(V5E_EDGE))
    assert ar > 0.0
    assert t2 >= t2_free + 2 * cfg.num_layers * ar - 1e-12


def test_sharded_engine_rejects_weight_quant(gemma_tiny):
    """HAQ weight dicts have no logical specs yet: the mesh + quant_bits<16
    combination must refuse loudly (kv_bits is the sharded memory lever)."""
    model, params = gemma_tiny
    with pytest.raises(NotImplementedError, match="weight quant"):
        Engine(model, params, _policy(quant_bits=8),
               mesh=make_serving_mesh(model=1))
