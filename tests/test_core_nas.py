"""NAS core (§2): binarization, latency LUT (Eq. 2), loss (Eq. 3), search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.supernet_lm import BACKBONE, CANDIDATE_OPS
from repro.core import latency_table as lt
from repro.core import nas
from repro.core import supernet as sn
from repro.core.hardware_model import V5E_EDGE, V5E_POD


def _tiny_backbone():
    cfg = BACKBONE.replace(num_layers=3, d_model=64, num_heads=4,
                           num_kv_heads=2, head_dim=16, d_ff=128,
                           vocab_size=512)
    return cfg.replace(ssm=cfg.ssm.__class__(d_state=16, expand=2,
                                             head_dim=16, n_groups=1,
                                             chunk=32))


def test_lut_shape_and_ordering():
    cfg = BACKBONE
    lut = lt.build_lut(cfg, batch=8, seq=2048, hw=V5E_POD)
    assert lut.shape == (cfg.num_layers, len(CANDIDATE_OPS))
    ops = list(CANDIDATE_OPS)
    row = np.asarray(lut[0])
    # zero op is free; local1k is no slower than full at same expansion
    assert row[ops.index("zero")] == 0.0
    assert row[ops.index("attn_local1k_e4")] <= \
        row[ops.index("attn_full_e4")] + 1e-12
    assert row[ops.index("attn_full_e2")] <= row[ops.index("attn_full_e4")]


def test_expected_latency_differentiable_and_convex_comb():
    lut = lt.build_lut(BACKBONE, 8, 2048, V5E_POD)
    alpha = jnp.zeros((BACKBONE.num_layers, len(CANDIDATE_OPS)))
    g = jax.grad(lambda a: lt.expected_latency(a, lut))(alpha)
    assert g.shape == alpha.shape and bool(jnp.any(g != 0))
    e = float(lt.expected_latency(alpha, lut))
    assert float(jnp.min(lut.sum(0))) <= e * BACKBONE.num_layers * 10


def test_eq3_loss_forms():
    ncfg = nas.NASConfig(latency_loss="mul", beta=0.5)
    # below target -> pure CE; above target -> penalized
    assert float(nas.combined_loss(2.0, 1.0, 2.0, ncfg)) == 2.0
    assert float(nas.combined_loss(2.0, 4.0, 2.0, ncfg)) > 2.0
    ncfg_add = nas.NASConfig(latency_loss="add", beta=0.5)
    assert float(nas.combined_loss(2.0, 4.0, 2.0, ncfg_add)) == 2.0 + 0.5


def test_single_path_binarization():
    """Only the sampled path executes: zero-gated blocks leave x unchanged."""
    cfg = _tiny_backbone()
    params, alpha = sn.init_supernet(jax.random.PRNGKey(0), cfg)
    gates = jnp.asarray([CANDIDATE_OPS.index("zero")] * cfg.num_layers)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    h = sn.supernet_forward(params, alpha, gates, batch, cfg)
    # all-zero arch == embedding passthrough + final norm: finite, no NaN
    assert bool(jnp.all(jnp.isfinite(h)))


def test_alpha_receives_gradient():
    cfg = _tiny_backbone()
    params, alpha = sn.init_supernet(jax.random.PRNGKey(0), cfg)
    gates = jnp.asarray([0] * cfg.num_layers)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    g = jax.grad(lambda a: sn.supernet_loss(params, a, gates, batch, cfg))(
        alpha)
    assert bool(jnp.any(g != 0)), "straight-through gradient must reach alpha"


@pytest.mark.slow
def test_search_shrinks_latency_under_budget():
    cfg = _tiny_backbone()
    lut = lt.build_lut(cfg, 4, 64, V5E_EDGE)
    res = nas.search(nas.synthetic_lm_data(cfg, batch=4, seq=64),
                     hw=V5E_EDGE,
                     ncfg=nas.NASConfig(steps=60, warmup_steps=20, batch=4,
                                        seq=64, log_every=20, alpha_lr=0.08),
                     cfg=cfg, lut=lut)
    assert len(res["arch"]) == cfg.num_layers
    # latency term drives E[LAT] to (near) the budget, CE stays finite
    assert res["e_lat_us"] <= res["lat_ref_us"] * 1.1
    assert all(h["val_ce"] == h["val_ce"] for h in res["history"])  # no NaN
