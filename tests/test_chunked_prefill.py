"""Chunked prefill: kernel parity vs the dense oracle, engine greedy
equivalence vs whole-prompt prefill (fp and quantized pools), mid-prefill
preemption round-trip exactness, and the no-dense-prompt-KV jaxpr
guarantee."""
import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.configs import tiny_config
from repro.kernels import ops, ref
from repro.kernels import paged_attention as pa
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.serving.engine import AdmissionPolicy, Engine, Request


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _req(rid, S, gen, *, vocab=512, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(2, vocab, S, dtype=np.int64)
                   .astype(np.int32), max_new=gen)


# ------------------------------------------------------ kernel parity ------
def _prefill_case(B, H, K, hd, page, n_blocks, Sq, *, num_pages=11, seed=0):
    """Random pool + ragged chunk-start positions: each sequence's chunk
    begins at a different resident-prefix length, pages shuffled, unused
    page-table tails on the poisoned scratch page 0."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool_k = jax.random.normal(ks[0], (num_pages, page, K, hd), jnp.float32)
    pool_v = jax.random.normal(ks[1], (num_pages, page, K, hd), jnp.float32)
    pool_k = pool_k.at[0].set(37.0)          # a masking bug reads these
    pool_v = pool_v.at[0].set(-53.0)
    q = jax.random.normal(ks[2], (B, Sq, H, hd), jnp.float32)
    positions = rng.integers(0, n_blocks * page - Sq, B).astype(np.int32)
    positions[0] = 0                          # empty-prefix edge case
    pt = np.zeros((B, n_blocks), np.int32)
    for b in range(B):
        need = (positions[b] + Sq - 1) // page + 1
        pt[b, :need] = rng.choice(np.arange(1, num_pages), need,
                                  replace=False)
    return (q, pool_k, pool_v, jnp.asarray(pt),
            jnp.asarray(positions, jnp.int32))


@pytest.mark.parametrize("page,n_blocks", [(8, 6), (16, 4), (32, 2)])
@pytest.mark.parametrize("Sq", [1, 5, 16])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (24, 0.0), (0, 30.0)])
@pytest.mark.parametrize("H,K", [(4, 2), (4, 1)])
def test_prefill_kernel_parity(page, n_blocks, Sq, window, cap, H, K):
    """Pallas chunked-prefill kernel (interpret) and the pure-JAX walk both
    match the dense gather+mask oracle across chunk sizes, page sizes,
    local windows, GQA shapes, ragged chunk starts, and scratch tails.
    Sq == 1 degenerates to the decode walk's semantics."""
    q, pk, pv, pt, pos = _prefill_case(3, H, K, 32, page, n_blocks, Sq)
    want = ref.paged_prefill_dense_ref(q, pk, pv, pt, pos,
                                       window=window, cap=cap)
    got_k = pa.paged_prefill_fwd(q, pk, pv, pt, pos, window=window,
                                 cap=cap, interpret=True)
    got_r = ref.paged_prefill_ref(q, pk, pv, pt, pos, window=window,
                                  cap=cap)
    assert float(jnp.max(jnp.abs(got_k - want))) < 1e-5
    assert float(jnp.max(jnp.abs(got_r - want))) < 1e-5


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("page,Sq", [(8, 6), (16, 16)])
@pytest.mark.parametrize("window", [0, 24])
def test_prefill_kernel_parity_quant(bits, page, Sq, window):
    """The fused-dequant chunked-prefill walk (ref and Pallas interpret)
    matches the dense oracle over the dequantized pool exactly — the
    quantization error lives in the pool contents, not the walk."""
    q, pk, pv, pt, pos = _prefill_case(2, 4, 2, 32, page, 4, Sq, seed=5)
    qk, sk = ref.quantize_kv(pk, bits)
    qv, sv = ref.quantize_kv(pv, bits)
    want = ref.paged_prefill_dense_ref(
        q, ref.dequantize_kv(qk, sk, bits), ref.dequantize_kv(qv, sv, bits),
        pt, pos, window=window)
    got_r = ops.paged_attention_prefill_quant(q, qk, sk, qv, sv, pt, pos,
                                              window=window, mode="ref")
    got_k = ops.paged_attention_prefill_quant(q, qk, sk, qv, sv, pt, pos,
                                              window=window, mode="pallas")
    assert float(jnp.max(jnp.abs(got_r - want))) < 1e-5
    assert float(jnp.max(jnp.abs(got_k - want))) < 1e-5


def test_prefill_chunk_overruns_page_table():
    """Regression: a final chunk whose padding extends past the page-table
    width (Sq not dividing the model length) must not corrupt the REAL
    query rows — the ref walk used to stage the overrun blocks' all-masked
    scores at a clamped offset, clobbering the last real block."""
    page, n_blocks, Sq = 16, 6, 64          # chunk spans blocks 4..7 of 6
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    pk = jax.random.normal(ks[0], (9, page, 2, 32), jnp.float32)
    pv = jax.random.normal(ks[1], (9, page, 2, 32), jnp.float32)
    q = jax.random.normal(ks[2], (1, Sq, 4, 32), jnp.float32)
    pt = jnp.asarray(np.arange(1, n_blocks + 1, dtype=np.int32)[None])
    pos = jnp.asarray([64], jnp.int32)      # real rows: qpos 64..95
    want = ref.paged_prefill_dense_ref(q, pk, pv, pt, pos)
    got_r = ref.paged_prefill_ref(q, pk, pv, pt, pos)
    got_k = pa.paged_prefill_fwd(q, pk, pv, pt, pos, interpret=True)
    real = slice(0, n_blocks * page - 64)   # rows whose qpos < T
    assert float(jnp.max(jnp.abs(got_r[:, real] - want[:, real]))) < 1e-5
    assert float(jnp.max(jnp.abs(got_k[:, real] - want[:, real]))) < 1e-5


# ---------------------------------------------- engine greedy equivalence --
@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")     # local/global mix + softcap + GQA
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.mark.parametrize("chunk", [4, 16, 32])
@pytest.mark.parametrize("page_size", [8, 16])
def test_chunked_matches_whole_prompt(gemma_tiny, chunk, page_size):
    """Chunked greedy decode is token-identical to the whole-prompt bucket
    prefill baseline across chunk sizes x page sizes (prompts span the
    local window and cross page and chunk boundaries)."""
    model, params = gemma_tiny
    reqs = [_req(0, 37, 8), _req(1, 44, 6), _req(2, 7, 5), _req(3, 16, 4)]
    outs = {}
    for mode, chunked in (("whole", False), ("chunked", True)):
        engine = Engine(model, params,
                        _policy(prefill_chunk=chunk, page_size=page_size),
                        chunked_prefill=chunked)
        outs[mode] = engine.run([_req(r.rid, len(r.prompt), r.max_new)
                                 for r in reqs])
        if chunked:
            assert engine.stats["prefill_chunks"] >= sum(
                -(-len(r.prompt) // chunk) for r in reqs)
    for r in reqs:
        assert np.array_equal(outs["whole"][r.rid], outs["chunked"][r.rid]), \
            (r.rid, chunk, page_size)


def test_chunk_padding_past_model_len(gemma_tiny):
    """Regression: prompts whose final chunk pads beyond max_model_len
    (chunk does not divide the model length) stay token-identical —
    overflow rows land on the scratch page, never on live pages or
    undefined scatter indices."""
    model, params = gemma_tiny
    pol = _policy(max_model_len=96, prefill_chunk=64, max_batch=2)
    reqs = [_req(0, 85, 11), _req(1, 90, 6)]    # prompts fill the table
    outs = {}
    for mode, chunked in (("whole", False), ("chunked", True)):
        engine = Engine(model, params, pol, chunked_prefill=chunked)
        outs[mode] = engine.run([_req(r.rid, len(r.prompt), r.max_new)
                                 for r in reqs])
    for r in reqs:
        assert np.array_equal(outs["whole"][r.rid], outs["chunked"][r.rid]), \
            r.rid


def test_chunked_matches_sequential_baseline(gemma_tiny):
    """Chunked engine output equals the sequential dense baseline — the
    repo-wide exactness anchor — on a mixed trace."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(prefill_chunk=8))
    reqs = [_req(i, 5 + 9 * i, 6) for i in range(4)]
    outs = engine.run(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid


@pytest.mark.parametrize("kv_bits", [8, (4, 8)])
def test_chunked_quantized_pool_matches_single_chunk(gemma_tiny, kv_bits):
    """On quantized pools the chunk size must not change outputs either:
    many small chunks == one whole-prompt-sized chunk, bit-identically
    (quantize-on-write uses per-token scales, so chunking never re-scales
    resident tokens)."""
    model, params = gemma_tiny
    reqs = [_req(0, 37, 6), _req(1, 22, 5)]
    outs = {}
    for name, chunk in (("small", 8), ("whole", 64)):
        engine = Engine(model, params,
                        _policy(prefill_chunk=chunk, kv_bits=kv_bits))
        outs[name] = engine.run([_req(r.rid, len(r.prompt), r.max_new)
                                 for r in reqs])
    for r in reqs:
        assert np.array_equal(outs["small"][r.rid], outs["whole"][r.rid]), \
            r.rid


# ------------------------------------------------- mid-prefill preemption --
def test_mid_prefill_preemption_roundtrip(gemma_tiny):
    """A sequence preempted in the middle of its prompt chunks (pages freed,
    requeued) restarts at re-admission and still produces exactly the
    baseline greedy tokens."""
    model, params = gemma_tiny
    # page 2, 35 usable pages: seq 0 (9-prompt, 5 pages) decodes from tick
    # 3 and crosses a page boundary every other tick (growths at ticks 4
    # and 6); seq 1's 57-token prompt reserves 29 pages and chunks for 8
    # ticks at chunk 8, leaving ONE free page after admission — seq 0's
    # second growth exhausts the pool at tick 6, while seq 1 (younger)
    # still owes two chunks, so the preemption victim is chunk-pending.
    engine = Engine(model, params,
                    _policy(max_batch=2, num_pages=36, page_size=2,
                            prefill_chunk=8))
    preempted_mid_prefill = []
    orig = engine.scheduler.preempt

    def spy(seq):
        if not seq.prefill_done:
            preempted_mid_prefill.append(
                (seq.req.rid, seq.prefill_progress, len(seq.req.prompt)))
        orig(seq)

    engine.scheduler.preempt = spy
    reqs = [_req(0, 9, 44), _req(1, 57, 6)]
    outs = engine.run(reqs)
    assert preempted_mid_prefill, \
        "trace did not preempt a mid-prefill sequence; retune the pool"
    rid, progress, S = preempted_mid_prefill[0]
    assert 0 < progress < S       # genuinely mid-prompt, chunk-aligned
    assert progress % 8 == 0
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid
    assert engine.kv.allocator.num_allocated == 0


def test_scheduler_gates_chunk_pending_sequences(gemma_tiny):
    """Chunk-pending sequences hold a batch slot but never enter the
    decode batch; they join it the tick their final chunk lands."""
    model, params = gemma_tiny
    engine = Engine(model, params, _policy(max_batch=2, prefill_chunk=8))
    engine.submit(_req(0, 4, 12))         # ready after one chunk
    engine.submit(_req(1, 33, 4))         # 5 chunks of 8
    for tick in range(5):
        engine.step()
        pending = engine.scheduler.prefill_pending()
        ready = engine.scheduler.decode_ready()
        if tick < 4:
            assert [s.req.rid for s in pending] == [1]
            assert [s.req.rid for s in ready] == [0]
            assert pending[0].prefill_progress == 8 * (tick + 1)
            assert not pending[0].generated    # no token before last chunk
        else:
            assert not pending                 # final chunk landed
    assert any(s.req.rid == 1 and s.generated
               for s in engine.scheduler.active.values())


# ----------------------------------------------------- pool span writer ----
def test_write_prefill_span_offsets(gemma_tiny):
    """pool.write_prefill(start=...) lands a chunk's full-layout cache at
    its page-aligned span: two chunk writes == one whole write."""
    model, params = gemma_tiny
    from repro.serving.engine.pool import PagedKVPool
    prompt = np.asarray(_req(0, 32, 1).prompt)
    _, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                             cache_layout="full")
    whole = PagedKVPool(model, 6, 16)
    whole.write_prefill(cache, [1, 2])
    spans = PagedKVPool(model, 6, 16)
    half = jax.tree.map(lambda c: c[:, :, :16], cache)
    rest = jax.tree.map(lambda c: c[:, :, 16:], cache)
    spans.write_prefill(half, [1, 2])
    spans.write_prefill(rest, [1, 2], start=16)
    eq = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                      whole.pool, spans.pool)
    assert all(jax.tree.leaves(eq))
    with pytest.raises(ValueError, match="page-aligned"):
        spans.write_prefill(rest, [1, 2], start=8)


# ------------------------------------------------------- jaxpr guarantee ---
def _iter_avals(jaxpr):
    from jax.core import Jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            subs = p if isinstance(p, (list, tuple)) else [p]
            for s in subs:
                inner = getattr(s, "jaxpr", None)
                if isinstance(s, Jaxpr):
                    yield from _iter_avals(s)
                elif isinstance(inner, Jaxpr):
                    yield from _iter_avals(inner)


def test_chunked_prefill_never_builds_dense_prompt_kv(gemma_tiny):
    """The jitted chunk forward contains no chronological dense prompt KV
    intermediate — neither the flat (1, max_pages*page, K, hd) gather nor
    its pre-reshape (1, max_pages, page, K, hd) form."""
    model, params = gemma_tiny
    pol = _policy()
    maxp, page = pol.pages_per_seq, pol.page_size
    K, hd = model.cfg.num_kv_heads, model.cfg.resolved_head_dim
    pool = model.init_pool(9, page)
    pt = jnp.zeros((1, maxp), jnp.int32)
    toks = jnp.zeros((1, pol.prefill_chunk), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: model.prefill_chunk_paged(*a))(params, pool, pt, toks,
                                                  pos)
    banned = {(1, maxp * page, K, hd), (1, maxp, page, K, hd)}
    dense = [a for a in _iter_avals(jaxpr.jaxpr)
             if getattr(a, "shape", None) in banned]
    assert not dense, dense
    # positive control: the dense oracle must trip the same scan
    q = jnp.zeros((1, pol.prefill_chunk, model.cfg.num_heads, hd),
                  jnp.bfloat16)
    pk = jax.tree.leaves(pool)[0][0]          # (P, page, K, hd)
    jx = jax.make_jaxpr(
        lambda *a: ref.paged_prefill_dense_ref(*a))(q, pk, pk, pt, pos)
    hits = [a for a in _iter_avals(jx.jaxpr)
            if getattr(a, "shape", None) in banned]
    assert hits, "aval scan lost its teeth"


# ----------------------------------------------------------- slow smoke ----
@pytest.mark.slow
def test_chunked_long_trace_smoke(gemma_tiny):
    """CI smoke: a 10-request trace with long prompts on a constrained pool
    — admission, chunking, growth, preemption (possibly mid-prefill), and
    backfill in one run, every output checked against the baseline."""
    model, params = gemma_tiny
    engine = Engine(model, params,
                    _policy(max_batch=3, num_pages=9, prefill_chunk=8))
    rng = np.random.default_rng(11)
    reqs = []
    for i in range(10):
        S = int(rng.integers(4, 49))
        gen = int(rng.integers(4, 64 - S))
        reqs.append(Request(rid=i, prompt=rng.integers(
            2, model.cfg.vocab_size, S).astype(np.int32), max_new=gen))
    outs = engine.run(reqs)
    assert engine.stats["prefill_chunks"] > len(reqs)
    for r in reqs:
        want = np.asarray(generate(model, params,
                                   jnp.asarray(r.prompt[None]),
                                   r.max_new)[0])
        assert np.array_equal(want, outs[r.rid]), r.rid
    assert engine.kv.allocator.num_allocated == 0
