"""Pallas kernels vs kernels/ref.py oracles: shape/dtype/block sweeps in
interpret mode (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels import quant_matmul as qmm


def _xw(M, K, N, dtype, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = (jax.random.normal(kw, (K, N), jnp.float32) * 0.1)
    return x, w


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256),
                                   (128, 256, 512), (8, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w8a16_shapes_dtypes(M, K, N, dtype):
    x, w = _xw(M, K, N, dtype)
    wq, ws = ref.quantize_w8(w)
    got = qmm.quant_matmul_w8a16(x, wq, ws, bm=min(32, M), bn=128, bk=128,
                                 interpret=True)
    want = ref.quant_matmul_w8a16(x, wq, ws)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert got.dtype == x.dtype
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol * max(1.0, float(jnp.max(jnp.abs(want)))), err


@pytest.mark.parametrize("bm,bn,bk", [(16, 64, 64), (32, 128, 128),
                                      (64, 128, 256)])
def test_w8a16_block_sweep(bm, bn, bk):
    x, w = _xw(64, 512, 256, jnp.float32)
    wq, ws = ref.quantize_w8(w)
    got = qmm.quant_matmul_w8a16(x, wq, ws, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
    want = ref.quant_matmul_w8a16(x, wq, ws)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256)])
def test_w4a16(M, K, N):
    x, w = _xw(M, K, N, jnp.float32)
    packed, scale = ref.quantize_w4_packed(w)
    got = qmm.quant_matmul_w4a16(x, packed, scale, bm=min(32, M), bn=128,
                                 bk=128, interpret=True)
    want = ref.quant_matmul_w4a16(x, packed, scale)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4
    # int4 packing really halves the weight bytes
    assert packed.size == w.size // 2 and packed.dtype == jnp.int8


def test_w4_unpack_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    packed, scale = ref.quantize_w4_packed(w)
    unpacked = ref.unpack_w4(packed)
    assert int(jnp.max(unpacked)) <= 7 and int(jnp.min(unpacked)) >= -7
    rel = float(jnp.linalg.norm(unpacked * scale[None, :] - w)
                / jnp.linalg.norm(w))
    assert rel < 0.15, rel  # int4 per-channel ~ 11% error on gaussian


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256)])
def test_w8a8(M, K, N):
    x, w = _xw(M, K, N, jnp.float32)
    wq, ws = ref.quantize_w8(w)
    xq, xs = ref.quantize_a8(x)
    got = qmm.quant_matmul_w8a8(xq, xs, wq, ws, bm=min(32, M), bn=128,
                                bk=128, out_dtype=jnp.float32,
                                interpret=True)
    want = ref.quant_matmul_w8a8(xq, xs, wq, ws, out_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 128, 0.0), (False, 0, 0.0), (True, 0, 30.0)])
@pytest.mark.parametrize("H,K", [(4, 2), (2, 2), (4, 1)])
def test_flash_kernel(causal, window, cap, H, K):
    B, S, hd = 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, bq=64, bkv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


def test_quant_dot_hook_end_to_end():
    """The HAQ dot hook with use_kernel routes through the Pallas kernel and
    stays close to the bf16 baseline at W8A16."""
    from repro.core.quantization import make_quant_dot
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256)) * 0.05
    dot_k = make_quant_dot({"site": (8, 16)}, use_kernel=True)
    dot_f = make_quant_dot({"site": (8, 16)}, use_kernel=False)
    got = dot_k(x, w, "site")
    want = dot_f(x, w, "site")
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 1e-3, rel
