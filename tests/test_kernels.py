"""Pallas kernels vs kernels/ref.py oracles: shape/dtype/block sweeps in
interpret mode (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels import quant_matmul as qmm


def _xw(M, K, N, dtype, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = (jax.random.normal(kw, (K, N), jnp.float32) * 0.1)
    return x, w


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256),
                                   (128, 256, 512), (8, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w8a16_shapes_dtypes(M, K, N, dtype):
    x, w = _xw(M, K, N, dtype)
    wq, ws = ref.quantize_w8(w)
    got = qmm.quant_matmul_w8a16(x, wq, ws, bm=min(32, M), bn=128, bk=128,
                                 interpret=True)
    want = ref.quant_matmul_w8a16(x, wq, ws)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert got.dtype == x.dtype
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol * max(1.0, float(jnp.max(jnp.abs(want)))), err


@pytest.mark.parametrize("bm,bn,bk", [(16, 64, 64), (32, 128, 128),
                                      (64, 128, 256)])
def test_w8a16_block_sweep(bm, bn, bk):
    x, w = _xw(64, 512, 256, jnp.float32)
    wq, ws = ref.quantize_w8(w)
    got = qmm.quant_matmul_w8a16(x, wq, ws, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
    want = ref.quant_matmul_w8a16(x, wq, ws)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256)])
def test_w4a16(M, K, N):
    x, w = _xw(M, K, N, jnp.float32)
    packed, scale = ref.quantize_w4_packed(w)
    got = qmm.quant_matmul_w4a16(x, packed, scale, bm=min(32, M), bn=128,
                                 bk=128, interpret=True)
    want = ref.quant_matmul_w4a16(x, packed, scale)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4
    # int4 packing really halves the weight bytes
    assert packed.size == w.size // 2 and packed.dtype == jnp.int8


def test_w4_unpack_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    packed, scale = ref.quantize_w4_packed(w)
    unpacked = ref.unpack_w4(packed)
    assert int(jnp.max(unpacked)) <= 7 and int(jnp.min(unpacked)) >= -7
    rel = float(jnp.linalg.norm(unpacked * scale[None, :] - w)
                / jnp.linalg.norm(w))
    assert rel < 0.15, rel  # int4 per-channel ~ 11% error on gaussian


@pytest.mark.parametrize("M,K,N", [(32, 256, 128), (64, 512, 256)])
def test_w8a8(M, K, N):
    x, w = _xw(M, K, N, jnp.float32)
    wq, ws = ref.quantize_w8(w)
    xq, xs = ref.quantize_a8(x)
    got = qmm.quant_matmul_w8a8(xq, xs, wq, ws, bm=min(32, M), bn=128,
                                bk=128, out_dtype=jnp.float32,
                                interpret=True)
    want = ref.quant_matmul_w8a8(xq, xs, wq, ws, out_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 128, 0.0), (False, 0, 0.0), (True, 0, 30.0)])
@pytest.mark.parametrize("H,K", [(4, 2), (2, 2), (4, 1)])
def test_flash_kernel(causal, window, cap, H, K):
    B, S, hd = 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, bq=64, bkv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


# ------------------------------------------------------- paged attention ---
def _paged_case(B, H, K, hd, page, n_blocks, *, num_pages=11, seed=0,
                dtype=jnp.float32):
    """Random pool + ragged page tables: each sequence at a different
    position, allocated pages shuffled, unused tails left on scratch page
    0 (whose contents are poisoned to catch any leak past the mask)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool_k = jax.random.normal(ks[0], (num_pages, page, K, hd),
                               jnp.float32).astype(dtype)
    pool_v = jax.random.normal(ks[1], (num_pages, page, K, hd),
                               jnp.float32).astype(dtype)
    # poison the scratch page: a masking bug shows up as a huge error
    pool_k = pool_k.at[0].set(37.0)
    pool_v = pool_v.at[0].set(-53.0)
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32).astype(dtype)
    positions = rng.integers(0, n_blocks * page, B).astype(jnp.int32)
    positions[0] = 0                          # scratch-tail-only edge case
    pt = np.zeros((B, n_blocks), np.int32)
    for b in range(B):
        need = positions[b] // page + 1
        pt[b, :need] = rng.choice(np.arange(1, num_pages), need,
                                  replace=False)
    return (q, pool_k, pool_v, jnp.asarray(pt),
            jnp.asarray(positions, jnp.int32))


@pytest.mark.parametrize("page,n_blocks", [(8, 6), (16, 4), (32, 2)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (24, 0.0), (0, 30.0)])
@pytest.mark.parametrize("H,K", [(4, 2), (2, 2), (4, 1)])
def test_paged_attention_kernel_parity(page, n_blocks, window, cap, H, K):
    """Pallas page-walk kernel (interpret) and pure-JAX block walk both
    match the dense gather+mask oracle across page sizes, local windows,
    GQA shapes, ragged positions, and scratch-page tails."""
    q, pk, pv, pt, pos = _paged_case(3, H, K, 32, page, n_blocks)
    want = ref.paged_attention_dense_ref(q, pk, pv, pt, pos,
                                         window=window, cap=cap)
    from repro.kernels import paged_attention as pa
    got_k = pa.paged_attention_fwd(q, pk, pv, pt, pos, window=window,
                                   cap=cap, interpret=True)
    got_r = ref.paged_attention_ref(q, pk, pv, pt, pos, window=window,
                                    cap=cap)
    assert float(jnp.max(jnp.abs(got_k - want))) < 1e-5
    assert float(jnp.max(jnp.abs(got_r - want))) < 1e-5


def test_paged_attention_bf16_and_dispatch():
    """ops.paged_attention: bf16 pools round-trip in q.dtype; mode="auto"
    resolves to the block walk off-TPU; unknown modes are rejected."""
    q, pk, pv, pt, pos = _paged_case(2, 4, 2, 32, 16, 3,
                                     dtype=jnp.bfloat16)
    want = ref.paged_attention_dense_ref(q, pk, pv, pt, pos)
    got = ops.paged_attention(q, pk, pv, pt, pos, mode="auto")
    assert got.dtype == jnp.bfloat16
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < 5e-2, err
    with pytest.raises(ValueError):
        ops.paged_attention(q, pk, pv, pt, pos, mode="dense")


def test_paged_attention_window_trim_matches_full_walk():
    """Window-trimmed walks (lo > 0) drop only blocks wholly outside the
    window: a local layer whose window spans everything equals the
    untrimmed causal walk."""
    q, pk, pv, pt, pos = _paged_case(3, 4, 2, 32, 16, 4, seed=3)
    full = ref.paged_attention_ref(q, pk, pv, pt, pos, window=0)
    wide = ref.paged_attention_ref(q, pk, pv, pt, pos,
                                   window=16 * 4)    # covers every block
    assert float(jnp.max(jnp.abs(full - wide))) < 1e-6


def test_quant_dot_hook_end_to_end():
    """The HAQ dot hook with use_kernel routes through the Pallas kernel and
    stays close to the bf16 baseline at W8A16."""
    from repro.core.quantization import make_quant_dot
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256)) * 0.05
    dot_k = make_quant_dot({"site": (8, 16)}, use_kernel=True)
    dot_f = make_quant_dot({"site": (8, 16)}, use_kernel=False)
    got = dot_k(x, w, "site")
    want = dot_f(x, w, "site")
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 1e-3, rel
