"""Hypothesis property tests on system invariants (assignment req. (c))."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantization as q
from repro.core.hardware_model import V5E_EDGE, V5E_POD, linear_cost
from repro.core.pruning import keep_mask
from repro.core.haq import enforce_budget, enumerate_sites, resource, W_BITS, A_BITS
from repro.configs import get_config
from repro.optim.adamw import quantize_moment, dequantize_moment

SHORT = settings(max_examples=25, deadline=None)


@SHORT
@given(bits=st.integers(2, 8), seed=st.integers(0, 100),
       rows=st.integers(1, 9), cols=st.integers(1, 65))
def test_fake_quant_bounded_error(bits, seed, rows, cols):
    """|w - Q(w)| <= scale/2 per element (uniform quantizer bound)."""
    w = np.random.default_rng(seed).standard_normal((rows, cols))
    w = jnp.asarray(w, jnp.float32)
    wq = q.fake_quant_weight(w, bits)
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = amax / (2.0 ** (bits - 1) - 1) + 1e-12
    assert bool(jnp.all(jnp.abs(w - wq) <= scale[None, :] * 0.5 + 1e-6))


@SHORT
@given(bits=st.integers(2, 8))
def test_fake_quant_monotone_in_bits(bits):
    """More bits never increases reconstruction error."""
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 32)),
                    jnp.float32)
    e1 = float(q.quant_error(w, bits))
    e2 = float(q.quant_error(w, bits + 1)) if bits < 8 else 0.0
    assert e2 <= e1 + 1e-6


@SHORT
@given(keep=st.floats(0.05, 1.0), n=st.integers(2, 300), seed=st.integers(0, 50))
def test_keep_mask_count(keep, n, seed):
    imp = jnp.asarray(np.random.default_rng(seed).standard_normal(n) ** 2)
    m = keep_mask(imp, keep)
    k = int(jnp.sum(m))
    assert 1 <= k <= n
    assert abs(k - round(keep * n)) <= 1
    # kept units are the most important ones
    thresh = jnp.sort(imp)[n - k]
    assert bool(jnp.all(imp[m > 0] >= thresh - 1e-9))


@SHORT
@given(w_bits=st.integers(2, 8), a_bits=st.sampled_from(A_BITS),
       tokens=st.integers(1, 4096))
def test_latency_monotone_in_bits(w_bits, a_bits, tokens):
    """Hardware-model latency & energy never increase when bits shrink."""
    c = linear_cost(tokens, 1024, 4096)
    for hw in (V5E_EDGE, V5E_POD):
        t1 = float(c.latency(hw, w_bits, a_bits))
        t2 = float(c.latency(hw, min(w_bits + 1, 8), a_bits))
        assert t1 <= t2 + 1e-12
        e1 = float(c.energy(hw, w_bits, a_bits))
        e2 = float(c.energy(hw, min(w_bits + 1, 8), a_bits))
        assert e1 <= e2 + 1e-12


@SHORT
@given(frac=st.floats(0.2, 1.0), seed=st.integers(0, 20))
def test_haq_budget_enforcement(frac, seed):
    """After back-off the policy ALWAYS meets the budget (paper's invariant),
    unless even all-min-bits cannot (then it equals all-min-bits)."""
    cfg = get_config("gemma2-2b")
    sites = enumerate_sites(cfg, batch=1, seq=128, decode=True)
    rng = np.random.default_rng(seed)
    wa = [(int(rng.choice(W_BITS)), int(rng.choice(A_BITS))) for _ in sites]
    base = resource(sites, [(8, 8)] * len(sites), V5E_EDGE, "latency")
    budget = frac * base
    out = enforce_budget(sites, wa, V5E_EDGE, budget, "latency")
    used = resource(sites, out, V5E_EDGE, "latency")
    floor = resource(sites, [(min(W_BITS), min(A_BITS))] * len(sites),
                     V5E_EDGE, "latency")
    assert used <= budget + 1e-12 or abs(used - floor) < 1e-12


@SHORT
@given(seed=st.integers(0, 40), rows=st.integers(1, 6),
       cols=st.sampled_from([16, 128, 384, 100]))
def test_moment_quantizer_roundtrip(seed, rows, cols):
    x = jnp.asarray(
        np.random.default_rng(seed).standard_normal((rows, cols)), jnp.float32)
    qd = dequantize_moment(quantize_moment(x, 128), x.shape)
    amax = float(jnp.max(jnp.abs(x))) + 1e-12
    assert float(jnp.max(jnp.abs(qd - x))) <= amax / 127.0 + 1e-6


@SHORT
@given(S=st.integers(2, 65), seed=st.integers(0, 10))
def test_ssd_chunk_invariance(S, seed):
    """SSD output is independent of the chunk size (state-passing exact)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(seed)
    B, H, P, G, N = 1, 2, 4, 1, 4
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    y1, f1 = ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=4)
    y2, f2 = ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=16)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3
    assert float(jnp.max(jnp.abs(f1 - f2))) < 1e-3
