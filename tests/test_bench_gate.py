"""Unit tests for scripts/check_bench_regression.py — the CI bench gate
that diffs BENCH_engine.json against a fresh run. The script lives
outside the package (scripts/), so it is loaded by file path; every test
drives the pure comparison functions on synthetic result dicts."""

import importlib.util
import json
import math
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = pathlib.Path(__file__).resolve().parents[1] / "scripts" / \
    "check_bench_regression.py"


@pytest.fixture(scope="module")
def gate():
    spec = importlib.util.spec_from_file_location("check_bench_regression",
                                                  _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _doc(**sections):
    base = {"schema": 1,
            "config": {"trace_seeds": {"mixed": 0, "long": 3}}}
    base.update(sections)
    return base


# ---------------------------------------------------------------- compare --
def test_compare_flags_large_drop(gate):
    baseline = _doc(mixed={"n": 16, "engine_tok_s": 100.0})
    fresh = _doc(mixed={"n": 16, "engine_tok_s": 80.0})   # -20%
    rows, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert len(failures) == 1 and "mixed.engine_tok_s" in failures[0]
    (row,) = rows
    assert row[0] == "mixed.engine_tok_s" and row[4].startswith("FAIL")


def test_compare_within_tolerance_passes(gate):
    baseline = _doc(mixed={"n": 16, "engine_tok_s": 100.0})
    fresh = _doc(mixed={"n": 16, "engine_tok_s": 90.0})    # -10%
    rows, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert failures == []
    assert rows[0][4] == "OK"
    # improvements never fail, whatever the magnitude
    fresh["mixed"]["engine_tok_s"] = 500.0
    _, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert failures == []


def test_compare_skips_mismatched_trace_sizes(gate):
    """A 4-request CI smoke is not comparable to a 16-request baseline:
    the drop must be reported as SKIP, not FAIL."""
    baseline = _doc(mixed={"n": 16, "engine_tok_s": 100.0})
    fresh = _doc(mixed={"n": 4, "engine_tok_s": 20.0})
    rows, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert failures == []
    assert "SKIP" in rows[0][4] and "size" in rows[0][4]
    assert not gate.sizes_match(baseline, fresh, "mixed")
    assert gate.sizes_match(baseline, baseline, "mixed")
    # a section without n is never comparable
    assert not gate.sizes_match(_doc(kv={"decode_tok_s": 1.0}),
                                _doc(kv={"decode_tok_s": 1.0}), "kv")


def test_compare_missing_and_new_sections(gate):
    baseline = _doc(mixed={"n": 16, "engine_tok_s": 100.0})
    fresh = _doc(kv={"n": 12, "fp16": {"decode_tok_s": 50.0}})
    rows, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert failures == []                        # missing != regressed
    by_path = {r[0]: r for r in rows}
    assert "SKIP" in by_path["mixed.engine_tok_s"][4]
    assert "NEW" in by_path["kv.fp16.decode_tok_s"][4]


def test_compare_only_reads_tok_s_leaves(gate):
    """Non-throughput leaves (preemptions, ms percentiles) never gate."""
    baseline = _doc(longprompt={"n": 6, "chunked": {
        "decode_tok_s": 100.0, "stall_p99_ms": 1.0, "prefill_chunks": 93}})
    fresh = _doc(longprompt={"n": 6, "chunked": {
        "decode_tok_s": 100.0, "stall_p99_ms": 99.0, "prefill_chunks": 5}})
    rows, failures = gate.compare(baseline, fresh, tolerance=0.15)
    assert failures == []
    assert [r[0] for r in rows] == ["longprompt.chunked.decode_tok_s"]


# ----------------------------------------------------- fresh-only checks --
def test_check_longprompt_floors(gate):
    ok = _doc(longprompt={"n": 6, "stall_p99_reduction": 4.0,
                          "decode_tok_s_ratio": 1.05})
    rows, failures = gate.check_longprompt(ok)
    assert failures == [] and all(r[4] == "OK" for r in rows)

    bad = _doc(longprompt={"n": 6, "stall_p99_reduction": 1.5,
                           "decode_tok_s_ratio": 0.5})
    _, failures = gate.check_longprompt(bad)
    assert len(failures) == 2

    # missing section / missing keys -> SKIP, not crash
    assert gate.check_longprompt(_doc()) == ([], [])
    rows, failures = gate.check_longprompt(_doc(longprompt={"n": 6}))
    assert failures == [] and all("SKIP" in r[4] for r in rows)


def test_check_sharded_floors(gate):
    ok = _doc(sharded={"outputs_identical": True,
                       "capacity": {"pages_scaling_2x": 2.0}})
    rows, failures = gate.check_sharded(ok)
    assert failures == [] and all(r[4] == "OK" for r in rows)

    diverged = _doc(sharded={"outputs_identical": False,
                             "capacity": {"pages_scaling_2x": 1.2}})
    _, failures = gate.check_sharded(diverged)
    assert len(failures) == 2
    assert any("diverged" in f for f in failures)

    assert gate.check_sharded(_doc()) == ([], [])


def _autotune_section(**over):
    sec = {"n": 4, "budget": 32, "candidates": 12, "admissible": 10,
           "default": {"decode_tok_s": 300.0},
           "searched": {"decode_tok_s": 360.0},
           "searched_vs_default": 1.2}
    sec.update(over)
    return sec


def test_check_autotune_floors(gate):
    ok = _doc(autotune=_autotune_section())
    rows, failures = gate.check_autotune(ok)
    assert failures == [] and all(r[4] == "OK" for r in rows)
    # exactly at the floor passes
    _, failures = gate.check_autotune(
        _doc(autotune=_autotune_section(searched_vs_default=0.95)))
    assert failures == []
    # a searched config that measured worse than the default fails
    _, failures = gate.check_autotune(
        _doc(autotune=_autotune_section(searched_vs_default=0.9)))
    assert len(failures) == 1 and "searched_vs_default" in failures[0]
    # a search that evaluated nothing fails
    _, failures = gate.check_autotune(
        _doc(autotune=_autotune_section(candidates=0, admissible=0)))
    assert len(failures) == 2
    # missing section -> no rows; missing keys -> SKIP, not crash
    assert gate.check_autotune(_doc()) == ([], [])
    rows, failures = gate.check_autotune(_doc(autotune={"n": 4}))
    assert failures == [] and all("SKIP" in r[4] for r in rows)


def test_validate_schema_autotune_required_keys(gate):
    assert gate.validate_schema(_doc(autotune=_autotune_section())) == []
    sec = _autotune_section()
    del sec["searched_vs_default"]
    del sec["budget"]
    problems = gate.validate_schema(_doc(autotune=sec), "fresh")
    assert any("searched_vs_default" in p for p in problems)
    assert any("budget" in p for p in problems)
    # default/searched sub-objects must carry the measured tok/s the
    # floors and the trajectory read
    problems = gate.validate_schema(
        _doc(autotune=_autotune_section(searched={"ttft_p50_ms": 1.0})))
    assert any("autotune.searched" in p and "decode_tok_s" in p
               for p in problems)
    problems = gate.validate_schema(_doc(autotune="not a dict"))
    assert any("not an object" in p for p in problems)


# -------------------------------------------------------- schema validate --
def test_validate_schema_accepts_committed_baseline(gate):
    repo = pathlib.Path(__file__).resolve().parents[1]
    doc = json.loads((repo / "BENCH_engine.json").read_text())
    assert gate.validate_schema(doc) == []


def test_validate_schema_rejects_nan_and_inf(gate):
    doc = _doc(mixed={"n": 16, "engine_tok_s": math.nan})
    problems = gate.validate_schema(doc, "fresh")
    assert len(problems) == 1 and "NaN" in problems[0]
    assert "mixed.engine_tok_s" in problems[0]

    doc = _doc(telemetry={"roofline_scale": {"decode": math.inf}})
    problems = gate.validate_schema(doc)
    assert any("non-finite" in p for p in problems)
    # None (null) is fine — unpredicted calibration groups use it
    assert gate.validate_schema(
        _doc(telemetry={"roofline_scale": {"decode": None}})) == []


def test_validate_schema_requires_seeds_and_version(gate):
    assert any("trace_seeds" in p for p in gate.validate_schema(
        {"schema": 1, "config": {}}))
    assert any("trace_seeds" in p for p in gate.validate_schema(
        {"schema": 1, "config": {"trace_seeds": {}}}))
    assert any("schema" in p for p in gate.validate_schema(
        {"config": {"trace_seeds": {"mixed": 0}}}))
    assert gate.validate_schema("not a dict") == ["doc: not a JSON object"]
    # NaN inside a list leaf is still caught
    problems = gate.validate_schema(
        _doc(extra={"xs": [1.0, math.nan]}))
    assert any("extra.xs.1" in p for p in problems)


# ------------------------------------------------------------ end-to-end --
def test_gate_cli_fails_on_schema_violation(gate, tmp_path):
    """The CLI exits 1 on a NaN fresh doc BEFORE comparing (a NaN tok/s
    would otherwise sail through every delta check)."""
    baseline = _doc(mixed={"n": 16, "engine_tok_s": 100.0})
    fresh = _doc(mixed={"n": 16, "engine_tok_s": math.nan})
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(baseline))
    fp.write_text(json.dumps(fresh))
    proc = subprocess.run(
        [sys.executable, str(_SCRIPT), "--baseline", str(bp),
         "--fresh", str(fp)], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "SCHEMA" in proc.stdout and "NaN" in proc.stdout

    fp.write_text(json.dumps(_doc(mixed={"n": 16, "engine_tok_s": 99.0})))
    proc = subprocess.run(
        [sys.executable, str(_SCRIPT), "--baseline", str(bp),
         "--fresh", str(fp)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout
    assert "no regressions" in proc.stdout
