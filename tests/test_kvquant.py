"""HAQ-searched KV-cache quantization (serving/kvquant): storage-mapping
round trips, fused-dequant kernel parity, bit-policy search + gating,
KV-aware admission capacity, quantized engine drift bounds, window-trim
page freeing, and the no-dense-fp-KV jaxpr guarantee."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, tiny_config
from repro.core import haq
from repro.core.hardware_model import V5E_EDGE
from repro.kernels import ops, ref
from repro.kernels import paged_attention as pa
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.models.transformer import normalize_kv_bits
from repro.serving import kvquant
from repro.serving.engine import (AdmissionPolicy, Engine, PageAllocator,
                                  Request, Scheduler, derive_policy)
from repro.serving.engine.admission import kv_bytes_per_token

# Documented greedy-drift tolerances for the FIXED untrained tiny subject
# and traces below (deterministic on CPU; measured ~0.61 / ~1.07). An
# untrained model's KV carries full-scale noise, so these are loose upper
# bounds on the serving regime, not quality claims — trained-subject
# quality ordering is benchmarks/table6's job.
DRIFT_TOL = {8: 1.0, 4: 1.6}
# Preemption round-trip: tokens generated before a preemption are folded
# into the prompt verbatim, so only post-resume tokens may drift.
PREEMPT_MATCH_TOL = 0.9


def _policy(**kw):
    base = dict(hw_name="test", max_model_len=64, page_size=16,
                num_pages=10_000, max_batch=4, prefill_chunk=16,
                quant_bits=16, decode_slo_s=0.03, est_decode_s=0.0,
                est_prefill_s=0.0)
    base.update(kw)
    return AdmissionPolicy(**base)


def _req(rid, S, gen, *, vocab=512, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(2, vocab, S)
                   .astype(np.int32), max_new=gen)


@pytest.fixture(scope="module")
def gemma_tiny():
    cfg = tiny_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ------------------------------------------------------- storage mapping --
def test_int4_pack_roundtrip_exact():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-7, 8, (3, 5, 2, 32)), jnp.int8)
    packed = ref.pack_int4_hd(q)
    assert packed.shape == (3, 5, 2, 16) and packed.dtype == jnp.int8
    assert jnp.array_equal(ref.unpack_int4_hd(packed), q)


@pytest.mark.parametrize("bits,hd", [(8, 32), (8, 16), (4, 32), (4, 16)])
@pytest.mark.parametrize("granularity", ["token", "page"])
def test_kv_roundtrip_bounded(bits, hd, granularity):
    x = jax.random.normal(jax.random.PRNGKey(bits + hd),
                          (3, 8, 2, hd), jnp.float32) * 2.0
    q, scale = kvquant.quantize_kv(x, bits, granularity=granularity)
    deq = kvquant.dequantize_kv(q, scale, bits, granularity=granularity)
    bound = scale[..., None] if granularity == "token" \
        else scale[..., None, :, None]
    assert bool(jnp.all(jnp.abs(deq - x) <= bound * 0.5 + 1e-6))
    # int4 really halves storage; scale tile is per (slot, head) or (head,)
    assert q.shape[-1] == (hd if bits == 8 else hd // 2)
    assert scale.shape == ((3, 8, 2) if granularity == "token" else (3, 2))


def test_kv_roundtrip_property():
    """Hypothesis sweep of the uniform-quantizer bound |x - deq| <= scale/2
    across (bits, head_dim, scale granularity) — the invariant every
    consumer of the page layout (writers, kernel, ref walk) relies on."""
    pytest.importorskip("hypothesis",
                        reason="optional dep: property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(bits=st.sampled_from([4, 8]),
           hd=st.sampled_from([2, 8, 16, 64]),
           gran=st.sampled_from(["token", "page"]),
           slots=st.integers(1, 9), heads=st.integers(1, 3),
           seed=st.integers(0, 50), amp=st.floats(1e-3, 100.0))
    def check(bits, hd, gran, slots, heads, seed, amp):
        x = jnp.asarray(np.random.default_rng(seed)
                        .standard_normal((slots, heads, hd)) * amp,
                        jnp.float32)
        q, scale = kvquant.quantize_kv(x, bits, granularity=gran)
        deq = kvquant.dequantize_kv(q, scale, bits, granularity=gran)
        bound = scale[..., None] if gran == "token" \
            else scale[..., None, :, None]
        assert bool(jnp.all(jnp.abs(deq - x) <= bound * 0.5
                            + 1e-6 * amp + 1e-9))
        # monotone: int8 reconstruction never worse than int4
        if bits == 4:
            q8, s8 = kvquant.quantize_kv(x, 8, granularity=gran)
            d8 = kvquant.dequantize_kv(q8, s8, 8, granularity=gran)
            assert float(jnp.max(jnp.abs(d8 - x))) <= \
                float(jnp.max(jnp.abs(deq - x))) + 1e-6 * amp

    check()


def test_kv_bits_inference_rejects_garbage():
    assert ref.kv_bits_of(jnp.zeros((2, 4, 1, 32), jnp.int8), 32) == 8
    assert ref.kv_bits_of(jnp.zeros((2, 4, 1, 16), jnp.int8), 32) == 4
    with pytest.raises(ValueError):
        ref.kv_bits_of(jnp.zeros((2, 4, 1, 8), jnp.int8), 32)


# -------------------------------------------------------- kernel parity ---
def _quant_case(B, H, K, hd, page, n_blocks, bits, *, num_pages=11, seed=0):
    """Random quantized pool + ragged page tables; scratch page 0 codes AND
    scales poisoned so any leak past the mask explodes the error."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool_k = jax.random.normal(ks[0], (num_pages, page, K, hd), jnp.float32)
    pool_v = jax.random.normal(ks[1], (num_pages, page, K, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    kq, ksc = ref.quantize_kv(pool_k, bits)
    vq, vsc = ref.quantize_kv(pool_v, bits)
    kq = kq.at[0].set(55)
    vq = vq.at[0].set(-55)
    ksc = ksc.at[0].set(97.0)
    vsc = vsc.at[0].set(83.0)
    positions = rng.integers(0, n_blocks * page, B).astype(np.int32)
    positions[0] = 0
    pt = np.zeros((B, n_blocks), np.int32)
    for b in range(B):
        need = positions[b] // page + 1
        pt[b, :need] = rng.choice(np.arange(1, num_pages), need,
                                  replace=False)
    return (q, kq, ksc, vq, vsc, jnp.asarray(pt),
            jnp.asarray(positions, jnp.int32))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("page,n_blocks", [(8, 6), (16, 4), (32, 2)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (24, 0.0), (0, 30.0)])
@pytest.mark.parametrize("H,K", [(4, 2), (2, 2), (4, 1)])
def test_paged_attention_quant_parity(bits, page, n_blocks, window, cap,
                                      H, K):
    """Fused-dequant Pallas kernel (interpret) and the pure-JAX quant walk
    both match the dense oracle evaluated on the dequantized pool, across
    bitwidths, page sizes, local windows, GQA shapes, ragged positions,
    and poisoned scratch pages/scales."""
    q, kq, ksc, vq, vsc, pt, pos = _quant_case(3, H, K, 32, page, n_blocks,
                                               bits)
    kd = ref.dequantize_kv(kq, ksc, bits)
    vd = ref.dequantize_kv(vq, vsc, bits)
    want = ref.paged_attention_dense_ref(q, kd, vd, pt, pos,
                                         window=window, cap=cap)
    got_k = pa.paged_attention_quant_fwd(q, kq, ksc, vq, vsc, pt, pos,
                                         window=window, cap=cap,
                                         interpret=True)
    got_r = ref.paged_attention_quant_ref(q, kq, ksc, vq, vsc, pt, pos,
                                          window=window, cap=cap)
    assert float(jnp.max(jnp.abs(got_k - want))) < 1e-5
    assert float(jnp.max(jnp.abs(got_r - want))) < 1e-5


def test_quant_dispatch_modes():
    q, kq, ksc, vq, vsc, pt, pos = _quant_case(2, 4, 2, 32, 16, 3, 8)
    want = ref.paged_attention_quant_ref(q, kq, ksc, vq, vsc, pt, pos)
    got = ops.paged_attention_quant(q, kq, ksc, vq, vsc, pt, pos,
                                    mode="auto")
    assert float(jnp.max(jnp.abs(got - want))) < 1e-6
    with pytest.raises(ValueError):
        ops.paged_attention_quant(q, kq, ksc, vq, vsc, pt, pos,
                                  mode="dense")


# ------------------------------------------------- pool layout & policy ---
def test_normalize_kv_bits_forms():
    cfg = tiny_config("gemma2-2b")          # period 2: (local, global)
    assert normalize_kv_bits(cfg, None) is None
    assert normalize_kv_bits(cfg, 16) is None
    assert normalize_kv_bits(cfg, (16, 16)) is None
    assert normalize_kv_bits(cfg, 8) == (8, 8)
    assert normalize_kv_bits(cfg, (4,)) == (4, 4)
    assert normalize_kv_bits(cfg, {"sub0": 4}) == (4, 16)
    # a searched policy (kv_sub{j} site names) round-trips as-is
    assert normalize_kv_bits(cfg, {"kv_sub0": 4, "kv_sub1": 8}) == (4, 8)
    assert normalize_kv_bits(cfg, [4, 8]) == (4, 8)
    with pytest.raises(ValueError):
        normalize_kv_bits(cfg, 5)
    with pytest.raises(ValueError):
        normalize_kv_bits(cfg, (4, 8, 16))   # 3 does not cycle into 2
    with pytest.raises(ValueError):
        normalize_kv_bits(cfg, {"sub2": 4})  # beyond the period
    with pytest.raises(ValueError):
        normalize_kv_bits(cfg, {"Sub0": 4})  # typo must not drop quant


def test_pool_specs_quantized_layout(gemma_tiny):
    model, _ = gemma_tiny
    cfg = model.cfg
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    G = cfg.num_layers // 2
    specs = model.pool_specs(9, 16, kv_bits=(4, 8))
    s4, s8 = specs["sub0"]["k"], specs["sub1"]["k"]
    assert s4["q"].shape == (G, 9, 16, K, hd // 2)
    assert s8["q"].shape == (G, 9, 16, K, hd)
    assert s4["q"].dtype == jnp.int8
    assert s4["scale"].shape == (G, 9, 16, K)
    assert s4["scale"].dtype == jnp.float32
    # fp slots keep the bf16 layout; all-16 collapses to it entirely
    mixed = model.pool_specs(9, 16, kv_bits={"sub0": 8})
    assert mixed["sub1"]["k"].dtype == jnp.bfloat16
    assert model.pool_specs(9, 16, kv_bits=16) == model.pool_specs(9, 16)


def test_enumerate_kv_sites_and_gate():
    cfg = get_config("gemma2-2b")
    sites = haq.enumerate_kv_sites(cfg, batch=1, ctx=8192)
    assert [s.name for s in sites] == ["kv_sub0", "kv_sub1"]
    local, glob = sites
    assert local.local and not glob.local
    assert local.eff_ctx == cfg.window_size and glob.eff_ctx == 8192
    assert kvquant.allowed_kv_bits(local) == (4, 8, 16)
    assert kvquant.allowed_kv_bits(glob) == (8, 16)
    # int8 halves the latency-model KV traffic, roughly
    t16 = glob.latency(V5E_EDGE, 16)
    t8 = glob.latency(V5E_EDGE, 8)
    assert t8 < 0.7 * t16


def test_search_kv_policy_budget_and_gate():
    cfg = get_config("gemma2-2b")
    # deterministic back-off: tight budget drops local slots to int4 first,
    # global slots floor at int8 (the sensitivity gate)
    res = kvquant.search_kv_policy(cfg, V5E_EDGE, max_model_len=4096,
                                   episodes=0, budget_frac=0.4)
    assert res["policy"] == {"kv_sub0": 4, "kv_sub1": 8}
    assert res["resource"] <= res["budget"] * 1.001
    assert res["kv_bytes_per_token"] < res["kv_bytes_per_token_fp"]
    # RL search: feasible unless even the gated floor cannot fit
    res = kvquant.search_kv_policy(cfg, V5E_EDGE, max_model_len=4096,
                                   episodes=4, budget_frac=0.55, seed=0)
    floor = [min(kvquant.allowed_kv_bits(s)) for s in
             haq.enumerate_kv_sites(cfg, 1, 4096)]
    feasible = res["resource"] <= res["budget"] * 1.001
    at_floor = res["bits"] == tuple(floor)
    assert feasible or at_floor
    assert all(b >= 8 for b, s in zip(res["bits"],
                                      haq.enumerate_kv_sites(cfg, 1, 4096))
               if not s.local)


def test_admission_capacity_scales_with_kv_bits():
    """Acceptance: at equal HBM budget the int8-KV policy fits >= 1.5x the
    resident sequences (and ~2x the pages) of the fp pool; the HAQ-mixed
    policy more. Scale tiles are priced in, so the ratios are honest."""
    cfg = get_config("gemma2-2b")
    per16 = kv_bytes_per_token(cfg)
    per8 = kv_bytes_per_token(cfg, 8)
    per48 = kv_bytes_per_token(cfg, (4, 8))
    assert per16 / per8 >= 1.5 and per16 / per48 >= 2.0
    # a generous SLO keeps the batch memory-bound so capacity is visible
    fp = derive_policy(cfg, V5E_EDGE, max_model_len=4096, decode_slo_s=1.0)
    q8 = derive_policy(cfg, V5E_EDGE, max_model_len=4096, decode_slo_s=1.0,
                       kv_bits=8)
    mx = derive_policy(cfg, V5E_EDGE, max_model_len=4096, decode_slo_s=1.0,
                       kv_bits=(4, 8))
    assert q8.num_pages >= 1.5 * fp.num_pages
    assert q8.max_batch >= 1.5 * fp.max_batch
    assert mx.num_pages > q8.num_pages
    assert q8.kv_bits == (8,) and mx.kv_bits == (4, 8)
    # quantized pages are smaller, so the same HBM must never be exceeded
    kv_bytes = (q8.num_pages - 1) * q8.page_size * per8
    assert kv_bytes + cfg.param_count() * 2 * q8.quant_bits / 16 \
        <= V5E_EDGE.hbm_bytes


# ------------------------------------------------------------- writers ----
def test_write_prefill_quantizes_on_write(gemma_tiny):
    """The pool writer's fused quantize-scatter stores the reference
    per-token per-head mapping: scale tiles match quantize_kv(cache) and
    every dequantized slot reconstructs the cache within the quantizer
    bound scale/2 (codes may differ on exact round-to-half ties across
    separately compiled jits — the bound is the contract)."""
    from repro.serving.engine.pool import PagedKVPool
    model, params = gemma_tiny
    kv = PagedKVPool(model, 6, 16, kv_bits=(4, 8))
    prompt = jnp.asarray(np.random.default_rng(0)
                         .integers(2, 512, (1, 32)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": prompt},
                             cache_layout="full")
    pages = [3, 1]
    kv.write_prefill(cache, pages)
    for j, bits in ((0, 4), (1, 8)):
        c = cache[f"sub{j}"]["k"][:, 0]                # (G, 32, K, hd)
        c = c.reshape(c.shape[0], 2, 16, *c.shape[2:]).astype(jnp.float32)
        _, want_s = kvquant.quantize_kv(c, bits)
        got = kv.pool[f"sub{j}"]["k"]
        for i, p in enumerate(pages):
            sc = got["scale"][:, p]
            assert jnp.allclose(sc, want_s[:, i], rtol=1e-5), (j, p)
            deq = kvquant.dequantize_kv(got["q"][:, p], sc, bits)
            assert bool(jnp.all(jnp.abs(deq - c[:, i])
                                <= sc[..., None] * 0.5 + 1e-6)), (j, p)


# ------------------------------------------------------- engine + drift ---
def _kv_trace(cfg, n=4):
    """The actual bench kv trace (same generator, same seed), so the drift
    tolerance asserted here covers what BENCH_engine.json publishes."""
    from benchmarks.bench_engine_throughput import (TRACE_SEEDS,
                                                    make_skewed_trace)
    return make_skewed_trace(cfg, n, seed=TRACE_SEEDS["kv"])


@pytest.mark.slow
def test_engine_int8_drift_bounded_on_bench_trace(gemma_tiny):
    """Acceptance: the int8-KV engine on the bench trace is token-identical
    to the fp pool until a drift-explained flip — teacher-forced max-abs
    logit drift is under the documented tolerance, and at each request's
    first divergence the fp top-2 margin is within 2x the measured drift
    (a larger flip would need a logit error above the bound)."""
    model, params = gemma_tiny
    reqs = _kv_trace(model.cfg)
    fp = Engine(model, params, _policy(max_model_len=128)).run(reqs)
    q8 = Engine(model, params,
                _policy(max_model_len=128, kv_bits=(8,))).run(reqs)
    worst = 0.0
    for r in reqs:
        rep = kvquant.greedy_drift(model, params, fp[r.rid],
                                   len(r.prompt), kv_bits=8)
        worst = max(worst, rep["max_abs"])
        a, b = fp[r.rid], q8[r.rid]
        S = len(r.prompt)
        div = np.nonzero(a[S:] != b[S:])[0]
        if len(div):
            gap = rep["margins"][div[0]]
            assert gap <= 2 * rep["max_abs"] + 1e-6, (r.rid, gap)
    assert worst <= DRIFT_TOL[8], worst


@pytest.mark.slow
def test_engine_quantized_preemption_roundtrip(gemma_tiny):
    """A quantized-pool run survives forced preemption + requeue: the
    non-preempted sequence is token-identical to the unpressured quantized
    run, pre-preemption tokens are preserved verbatim (prompt-extension),
    and overall per-token agreement stays above the stated tolerance
    (requantized KV after the resume re-prefill may drift)."""
    model, params = gemma_tiny
    reqs = [_req(0, 12, 44), _req(1, 12, 44)]
    pre = Engine(model, params,
                 _policy(max_batch=2, num_pages=7, kv_bits=(8,)))
    outs_pre = pre.run(reqs)
    assert pre.stats["preemptions"] >= 1
    assert pre.kv.allocator.num_allocated == 0
    no = Engine(model, params, _policy(max_batch=2, kv_bits=(8,)))
    outs_no = no.run(reqs)
    assert no.stats["preemptions"] == 0
    match = total = 0
    for r in reqs:
        S = len(r.prompt)
        a, b = outs_no[r.rid][S:], outs_pre[r.rid][S:]
        assert a.shape == b.shape == (44,)
        match += int(np.sum(a == b))
        total += len(a)
    assert match / total >= PREEMPT_MATCH_TOL, (match, total)


def test_engine_quantized_smoke_and_stats(gemma_tiny):
    """Fast tier-1 cover: a short int8 + HAQ-mixed engine run completes
    with clean bookkeeping and bounded drift on one stream."""
    model, params = gemma_tiny
    reqs = [_req(0, 8, 6), _req(1, 12, 5)]
    for kvb in ((8,), (4, 8)):
        eng = Engine(model, params, _policy(kv_bits=kvb))
        outs = eng.run(reqs)
        assert eng.kv_bits == normalize_kv_bits(model.cfg, kvb)
        assert eng.kv.allocator.num_allocated == 0
        for r in reqs:
            assert outs[r.rid].shape == (len(r.prompt) + r.max_new,)
        rep = kvquant.greedy_drift(model, params, outs[reqs[0].rid],
                                   len(reqs[0].prompt), kv_bits=kvb)
        assert rep["max_abs"] <= DRIFT_TOL[min(kvb)], (kvb, rep["max_abs"])


# ----------------------------------------------------------- window trim --
def test_scheduler_trim_window_releases_dead_blocks():
    s = Scheduler(PageAllocator(12, 16), 2, 160)
    s.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                     max_new=100))
    (seq,) = s.admit()
    seq.pos = 8
    for _ in range(5):
        seq.pages.extend(s.allocator.alloc(1))
    assert len(seq.pages) == 6
    before = s.allocator.num_allocated
    seq.pos = 90                      # window 32: kpos <= 58 dead
    freed = s.trim_window(seq, 32)
    # lo = (90 - 32 + 1) // 16 = 3 blocks wholly behind the window
    assert freed == 3
    assert s.allocator.num_allocated == before - 3
    assert seq.pages[:3] == [0, 0, 0] and all(p for p in seq.pages[3:])
    assert s.trim_window(seq, 32) == 0            # idempotent
    s.release(seq)                                # zeros skipped on free
    assert s.allocator.num_allocated == 0


def test_engine_window_trim_occupancy_drops_outputs_exact():
    """All-local model: the engine releases pages behind the window while
    decoding — peak pool occupancy stays at the window footprint instead of
    the full sequence — and greedy outputs stay token-identical to the
    sequential baseline (the walk never read those blocks)."""
    cfg = tiny_config("gemma2-2b").replace(attn_pattern=("local",))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, _policy(max_model_len=96, num_pages=100))
    r = _req(0, 8, 80)
    engine.submit(r)
    peak = 0
    while engine.scheduler.has_work():
        engine.step()
        peak = max(peak, engine.kv.allocator.num_allocated)
    # window 32 spans at most ceil((32 + 16)/16) + 1 = 4 live pages; the
    # untrimmed sequence would hold ceil(88/16) = 6
    assert peak <= 4
    assert engine.stats["trimmed_pages"] >= 2
    assert engine.kv.allocator.num_allocated == 0
    want = np.asarray(generate(model, params,
                               jnp.asarray(r.prompt[None]), r.max_new)[0])
    assert np.array_equal(want, engine._outputs[r.rid])


# ------------------------------------------------------------ jaxpr scan --
def _iter_avals(jaxpr):
    from jax.core import Jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            subs = p if isinstance(p, (list, tuple)) else [p]
            for s in subs:
                inner = getattr(s, "jaxpr", None)
                if isinstance(s, Jaxpr):
                    yield from _iter_avals(s)
                elif isinstance(inner, Jaxpr):
                    yield from _iter_avals(inner)


@pytest.mark.parametrize("kv_bits", [(8,), (4, 8)])
def test_quant_decode_never_builds_dense_fp_kv(gemma_tiny, kv_bits):
    """Acceptance: the quantized decode step materializes neither the
    chronological dense KV view nor a full-pool fp dequant — the only fp
    KV ever built is the per-block (B, page, K, hd) tile inside the walk."""
    model, params = gemma_tiny
    pol = _policy()
    B, maxp, page = pol.max_batch, pol.pages_per_seq, pol.page_size
    cfg = model.cfg
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    P, G = 9, cfg.num_layers // 2
    pool = model.init_pool(P, page, kv_bits=kv_bits)
    pt = jnp.zeros((B, maxp), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: model.decode_step_paged(*a))(params, pool, pt, tok, pos)
    banned = {(B, maxp * page, K, hd), (B, maxp, page, K, hd),
              (P, page, K, hd), (G, P, page, K, hd)}
    dense = [a for a in _iter_avals(jaxpr.jaxpr)
             if getattr(a, "shape", None) in banned
             and jnp.issubdtype(a.dtype, jnp.inexact)]
    assert not dense, dense
    # positive control: dequantizing the whole pool trips the same scan
    leaf = pool["sub1"]["k"] if len(kv_bits) > 1 else pool["sub0"]["k"]
    jx = jax.make_jaxpr(lambda q, s: kvquant.dequantize_kv(q, s, 8))(
        leaf["q"][0], leaf["scale"][0])
    hits = [a for a in _iter_avals(jx.jaxpr)
            if getattr(a, "shape", None) in banned
            and jnp.issubdtype(a.dtype, jnp.inexact)]
    assert hits, "aval scan lost its teeth"
