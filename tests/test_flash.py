"""XLA flash attention (models/flash.py) vs dense reference — forward and
gradients, across kinds/windows/softcaps/block shapes/GQA ratios."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import _attend, causal_mask, local_mask
from repro.models.flash import flash_attention


def dense(q, k, v, kind, window, cap):
    S, T = q.shape[1], k.shape[1]
    if kind == "local":
        m = local_mask(S, T, window)
    elif kind == "bidir":
        m = jnp.ones((1, 1, S, T), bool)
    else:
        m = causal_mask(S, T)
    return _attend(q, k, v, m, cap)


@pytest.mark.parametrize("kind,window,cap", [
    ("global", 0, 0.0), ("local", 64, 0.0), ("bidir", 0, 0.0),
    ("global", 0, 20.0), ("local", 100, 30.0),
])
@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (4, 1)])
def test_flash_matches_dense(kind, window, cap, H, K):
    B, S, hd = 2, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    do = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)

    f = lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, kind, window, cap, 64, 64) * do)
    g = lambda q, k, v: jnp.sum(dense(q, k, v, kind, window, cap) * do)
    of, gf = jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)
    od, gd = jax.value_and_grad(g, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(of - od)) / (abs(float(od)) + 1e-9) < 1e-3
    for a, b in zip(gf, gd):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3


@pytest.mark.parametrize("bq,bkv", [(32, 64), (128, 32), (256, 256)])
def test_flash_block_shapes(bq, bkv):
    B, S, H, K, hd = 1, 256, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    got = flash_attention(q, k, v, "global", 0, 0.0, bq, bkv)
    want = dense(q, k, v, "global", 0, 0.0)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4
