"""Quantized serving path (serving/quant.py): structure, packing, loss."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import tiny_config
from repro.models.api import build_model
from repro.models.params import abstract_params, logical_specs
from repro.serving import quant as sq



@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_quantize_defs_structure(setup):
    cfg, model, _ = setup
    defs_q = sq.quantize_defs(model.defs, default_bits=8)
    ap = abstract_params(defs_q)
    assert ap["lm_head"]["q"].dtype == jnp.int8
    assert ap["blocks"]["sub0"]["ffn"]["w_in"]["q"].dtype == jnp.int8
    # stacked scale carries the layer dim for lax.scan
    assert ap["blocks"]["sub0"]["ffn"]["w_in"]["scale"].shape[0] == \
        cfg.num_layers
    # norms stay fp32
    assert ap["final_norm"].dtype == jnp.float32
    # logical specs still resolve (axis tuples are leaves)
    ls = logical_specs(defs_q)
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    assert jax.tree.structure(ls, is_leaf=is_axes) == jax.tree.structure(ap)


def test_int4_halves_bytes(setup):
    _, model, _ = setup
    d8 = sq.quantize_defs(model.defs, default_bits=8)
    d4 = sq.quantize_defs(model.defs, default_bits=4)
    assert sq.avg_weight_bits(d4) < sq.avg_weight_bits(d8) < 16.0
    q8 = abstract_params(d8)["blocks"]["sub0"]["ffn"]["w_in"]["q"]
    q4 = abstract_params(d4)["blocks"]["sub0"]["ffn"]["w_in"]["q4"]
    assert q4.shape[-2] * 2 == q8.shape[-2]


def test_quantized_serving_equivalence(setup):
    cfg, model, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                              cfg.vocab_size)
    lg_fp, _ = model.prefill(params, {"tokens": toks})
    pq = sq.quantize_params(params, default_bits=8)
    lg_q, cache = model.prefill(pq, {"tokens": toks}, dot=sq.dequant_dot)
    dq, _ = model.decode_step(pq, cache, toks[:, -1:],
                              jnp.asarray(47, jnp.int32), dot=sq.dequant_dot)
    assert bool(jnp.all(jnp.isfinite(lg_q))) and \
        bool(jnp.all(jnp.isfinite(dq)))
    # loss-level fidelity on trained magnitudes is covered by the benchmark;
    # untrained tiny logits are near-uniform so only ask for clear top-1
    # correlation above chance (1/512)
    agree = jnp.mean((jnp.argmax(lg_fp, -1) == jnp.argmax(lg_q, -1))
                     .astype(jnp.float32))
    assert float(agree) > 0.1, float(agree)


def test_unpack_pack_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 32)) * 0.2
    pq = sq.quantize_params({"blocks": {"x": {"ffn": {"w_in": w}}}},
                            default_bits=4)
    d = pq["blocks"]["x"]["ffn"]["w_in"]
    assert "q4" in d and d["q4"].shape == (8, 8, 32)
    unpacked = sq._unpack4(d["q4"])
    assert unpacked.shape == w.shape
    assert int(jnp.max(unpacked)) <= 7 and int(jnp.min(unpacked)) >= -8
    scale = d["scale"].reshape(8, 1, 1)
    rel = float(jnp.linalg.norm(unpacked * scale - w) / jnp.linalg.norm(w))
    assert rel < 0.2, rel
