"""Per-architecture smoke: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and finiteness (assignment
requirement (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, assigned_cells, get_config, tiny_config
from repro.models.api import build_model

from conftest import tiny_batch

ARCH_IDS = [n for n in ARCHS if n != "supernet-lm"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch

    logits, _, _, _ = model.forward(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: v for k, v in tiny_batch(cfg).items() if k != "labels"}
    logits, cache = model.prefill(params, batch)
    assert logits.shape[1] == 1
    tok = jnp.ones((logits.shape[0], 1), jnp.int32)
    S = batch.get("tokens", batch.get("frames")).shape[1]
    lg, cache2 = model.decode_step(params, cache, tok,
                                   jnp.asarray(S - 1, jnp.int32))
    assert lg.shape == logits.shape
    assert bool(jnp.all(jnp.isfinite(lg))), arch
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_full_configs_match_assignment():
    """The full (non-tiny) configs carry the exact assigned dimensions."""
    spec = {
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d, arch
        assert cfg.num_heads == H and cfg.num_kv_heads == K, arch
        assert cfg.d_ff == ff and cfg.vocab_size == V, arch


def test_assigned_cells_cover_spec():
    cells = assigned_cells()
    # every arch has train/prefill/decode; sub-quadratic archs add long_500k
    assert ("mamba2-370m", "long_500k") in cells
    assert ("zamba2-1.2b", "long_500k") in cells
    assert ("gemma2-2b", "long_500k") in cells
    assert ("granite-3-8b", "long_500k") not in cells  # pure full attention
    assert len(cells) == 33


def test_moe_config_sizes():
    cfg = get_config("llama4-maverick-400b-a17b")
    # ~400B total, ~17B active
    assert 3.4e11 < cfg.param_count() < 4.6e11
    from repro.roofline.analysis import active_params
    # ~11B active in our text-only structure (a17b counts shared expert +
    # vision tower in the release; we model the text top-1 path)
    assert 0.9e10 < active_params(cfg) < 2.2e10
