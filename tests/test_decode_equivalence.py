"""prefill(S) + decode(1) must equal forward(S+1) at the last position —
exercises KV caches (full + ring-buffer local), SSM state carry, MoE routing
and the hybrid shared-attention cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, tiny_config
from repro.models.api import build_model

B, S = 2, 48  # S > tiny window (32) so gemma2's ring cache is exercised

CASES = [n for n in ARCHS if n not in ("supernet-lm", "whisper-large-v3",
                                       "llava-next-mistral-7b")]
# ssm/hybrid: chunked-SSD vs single-step recurrence drift in bf16
TOL = {"zamba2-1.2b": 5e-2, "mamba2-370m": 5e-2}


def _grow(cache, S):
    def grow(path, a):
        ks = jax.tree_util.keystr(path)
        if a.ndim == 5 and a.shape[2] == S and "mamba" not in ks:
            pad = [(0, 0)] * 5
            pad[2] = (0, 1)
            return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map_with_path(grow, cache)


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S + 1), 0,
                              cfg.vocab_size)
    full, _, _, _ = model.forward(params, {"tokens": toks})
    want = full[:, -1]
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    cache = _grow(cache, S)
    got, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                               jnp.asarray(S, jnp.int32))
    rel = float(jnp.max(jnp.abs(want - got[:, 0]))
                / (jnp.max(jnp.abs(want)) + 1e-9))
    assert rel < TOL.get(arch, 2e-2), (arch, rel)


def test_ssm_decode_exact_in_fp32():
    """With fp32 params+compute the chunked/recurrent paths agree closely."""
    cfg = tiny_config("mamba2-370m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    full, _, _, _ = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    got, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                               jnp.asarray(S, jnp.int32))
    rel = float(jnp.max(jnp.abs(full[:, -1] - got[:, 0]))
                / (jnp.max(jnp.abs(full[:, -1])) + 1e-9))
    assert rel < 2e-3, rel
