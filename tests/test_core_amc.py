"""AMC core (§3): env mechanics, budget feasibility, pruning correctness,
uniform-baseline comparison."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core import amc, pruning
from repro.core.rl.ddpg import DDPG, DDPGConfig
from repro.models.api import build_model

from conftest import tiny_batch


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, S=32)
    eval_loss = jax.jit(lambda p: model.loss(p, batch))
    return model, params, eval_loss


def test_layer_enumeration(setup):
    model, params, _ = setup
    layers = amc.enumerate_layers(model, tokens=4096)
    assert len(layers) == 2  # attn + ffn slots (period 1)
    assert {l.kind for l in layers} == {"attn", "ffn"}


def test_mask_prune_reduces_effective_params(setup):
    model, params, eval_loss = setup
    layers = amc.enumerate_layers(model, tokens=4096)
    masked = amc.apply_ratios(params, layers, [0.5] * len(layers))
    ffn = masked["blocks"]["sub0"]["ffn"]
    zero_cols = int(jnp.sum(jnp.all(ffn["w_in"] == 0, axis=(0, 1))))
    assert zero_cols == ffn["w_in"].shape[-1] // 2
    # loss changes but stays finite
    assert np.isfinite(float(eval_loss(masked)))


def test_budget_always_met(setup):
    model, params, eval_loss = setup
    acfg = amc.AMCConfig(target=0.5, episodes=1)
    env = amc.AMCEnv(model, params, eval_loss, acfg)
    agent = DDPG(DDPGConfig(state_dim=amc.STATE_DIM), seed=0)
    for _ in range(5):
        rec = env.rollout(agent, explore=True)
        assert rec["flops_frac"] <= acfg.target + 1e-6


def test_moe_expert_pruning():
    cfg = tiny_config("granite-moe-3b-a800m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    layers = amc.enumerate_layers(model, tokens=4096)
    assert any(l.kind == "moe" for l in layers)
    masked = amc.apply_ratios(params, layers, [0.5] * len(layers))
    router = masked["blocks"]["sub0"]["moe"]["router"]
    # pruned experts are routed around (-1e9 logit); router is layer-stacked
    lead = tuple(range(router.ndim - 1))
    assert int(jnp.sum(jnp.all(router < -1e8, axis=lead))) == 2


def test_magnitude_criterion_finds_planted_redundancy(setup):
    """Plant redundancy: half the FFN units scaled to ~0 in a briefly-trained
    model. Pruning by the magnitude criterion (keep important) must hurt less
    than pruning the important half (the criterion is informative — AMC's
    premise). Training first makes the live units actually matter."""
    model, params0, eval_loss = setup
    from repro.configs.base import OptimConfig, TrainConfig
    from repro.training import steps as steps_lib
    from conftest import tiny_batch
    tcfg = TrainConfig(optim=OptimConfig(lr=5e-3, warmup_steps=2,
                                         total_steps=30))
    state = steps_lib.init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(steps_lib.make_train_step(model, tcfg))
    batch = tiny_batch(model.cfg, B=2, S=32)
    for _ in range(30):
        state, _ = step(state, batch)
    params = state["params"]
    p = jax.tree.map(lambda x: x, params)
    ffn = dict(p["blocks"]["sub0"]["ffn"])
    dff = ffn["w_in"].shape[-1]
    kill = jnp.arange(dff) < dff // 2
    for k in ("w_in", "w_gate"):
        ffn[k] = ffn[k] * jnp.where(kill, 1e-3, 1.0)
    ffn["w_out"] = ffn["w_out"] * jnp.where(kill, 1e-3, 1.0)[:, None]
    p["blocks"]["sub0"]["ffn"] = ffn

    imp = pruning.ffn_importance(ffn)
    smart = dict(p, blocks={**p["blocks"], "sub0": {
        **p["blocks"]["sub0"],
        "ffn": pruning.mask_ffn(ffn, pruning.keep_mask(imp, 0.5))}})
    adversarial = dict(p, blocks={**p["blocks"], "sub0": {
        **p["blocks"]["sub0"],
        "ffn": pruning.mask_ffn(ffn, 1.0 - pruning.keep_mask(imp, 0.5))}})
    l_smart = float(eval_loss(smart))
    l_adv = float(eval_loss(adversarial))
    assert l_smart < l_adv, (l_smart, l_adv)
    # and the criterion indeed keeps the planted-important half
    assert bool(jnp.all(pruning.keep_mask(imp, 0.5)[dff // 2:] == 1.0))
