"""Optimizer, data pipeline, checkpointing, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import tiny_config
from repro.data.pipeline import DataConfig, batch_at
from repro.configs.base import OptimConfig, TrainConfig, ShapeConfig
from repro.distributed.fault_tolerance import (StragglerConfig,
                                               StragglerMonitor)
from repro.models.api import build_model
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.training import steps as steps_lib
from repro.training.loop import train


def test_adamw_converges_quadratic():
    ocfg = OptimConfig(lr=0.05, warmup_steps=1, total_steps=400,
                       weight_decay=0.0, grad_clip=10.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3, jnp.bfloat16)}
    state = adamw_init(params, ocfg)
    for _ in range(300):
        master = state["master"]["w"]
        grads = {"w": (master - target)}
        params, state, _ = adamw_update(grads, state, ocfg)
    assert float(jnp.max(jnp.abs(state["master"]["w"] - target))) < 0.05


def test_quantized_moments_track_fp32():
    for qm in (False, True):
        ocfg = OptimConfig(lr=0.01, warmup_steps=1, total_steps=100,
                           quantized_moments=qm)
        params = {"w": jnp.ones((4, 256), jnp.bfloat16)}
        state = adamw_init(params, ocfg)
        g = {"w": jnp.full((4, 256), 0.1, jnp.float32)}
        for _ in range(10):
            params, state, _ = adamw_update(g, state, ocfg)
        if qm:
            final_q = state["master"]["w"]
        else:
            final_f = state["master"]["w"]
    assert float(jnp.max(jnp.abs(final_q - final_f))) < 1e-3


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_data_deterministic_and_host_sharded():
    dcfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b1 = batch_at(dcfg, step=7)
    b2 = batch_at(dcfg, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(dcfg, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.zeros((2,), jnp.float32)}}
    ckpt.save(str(tmp_path), 7, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    tree = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [4, 5]
    # a .tmp dir (simulated crash) is never picked up
    (tmp_path / "step_9.tmp").mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_train_restart_exact(tmp_path):
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    tcfg = TrainConfig(optim=OptimConfig(lr=1e-3, total_steps=20),
                       checkpoint_dir=str(tmp_path), checkpoint_every=5,
                       log_every=100)
    train(model, shape, tcfg, num_steps=10, log=lambda r: None)
    out2 = train(model, shape, tcfg, num_steps=14, log=lambda r: None)
    # resumed run continues from step 10 (restored), history starts later
    assert out2["history"][0]["step"] >= 10


def test_straggler_monitor_flags_slow_steps():
    fired = []
    mon = StragglerMonitor(StragglerConfig(window=8, multiplier=2.0,
                                           strikes=2),
                           on_straggler=fired.append)
    for step in range(8):
        mon.record(step, 0.1)
    assert not mon.record(8, 0.15)
    assert mon.record(9, 0.5)       # breach 1
    assert mon.record(10, 0.5)      # breach 2 -> eviction callback
    assert fired and fired[0]["strikes"] == 2


def test_microbatched_train_step_matches_full():
    cfg = tiny_config("granite-3-8b")
    model = build_model(cfg)
    from conftest import tiny_batch
    batch = tiny_batch(cfg, B=4, S=32)
    base = TrainConfig(optim=OptimConfig(lr=1e-2, grad_clip=1e9))
    micro = TrainConfig(optim=OptimConfig(lr=1e-2, grad_clip=1e9),
                        microbatches=2)
    state = steps_lib.init_train_state(model, base, jax.random.PRNGKey(0))
    s1, m1 = steps_lib.make_train_step(model, base)(state, batch)
    state = steps_lib.init_train_state(model, micro, jax.random.PRNGKey(0))
    s2, m2 = steps_lib.make_train_step(model, micro)(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["opt"]["master"], s2["opt"]["master"])
    assert max(jax.tree.leaves(d)) < 5e-3
