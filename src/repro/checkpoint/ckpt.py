"""Fault-tolerant checkpointing: atomic, async, restart-discoverable.

Layout: <dir>/step_<N>/  arrays.npz (flattened pytree leaves) + tree.json
(structure + dtypes). Writes go to step_<N>.tmp then os.rename (atomic on
POSIX) so a mid-write crash never corrupts the restore point. An optional
background thread does the serialization (training continues), matching
async-checkpoint behaviour on real clusters. `latest_step` is the restart
discovery used by the trainer after preemption.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_SENTINEL = "DONE"


def _flatten(tree) -> tuple[Dict[str, np.ndarray], list, Any]:
    """Leaves as byte-views (np.savez cannot serialize ml_dtypes like
    bfloat16); dtypes/shapes recorded separately."""
    leaves, treedef = jax.tree.flatten(tree)
    arrays, meta = {}, []
    for i, x in enumerate(leaves):
        a = np.ascontiguousarray(np.asarray(x))
        meta.append({"dtype": str(a.dtype), "shape": list(a.shape)})
        arrays[f"leaf_{i}"] = a.view(np.uint8).reshape(-1)
    return arrays, meta, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> Path:
    """Atomic synchronous save. Returns the final path."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f"step_{step}.tmp"
    final = root / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, meta, treedef = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "tree.json").write_text(json.dumps({
        "treedef": str(treedef),
        "leaves": meta,
        "step": step,
        "time": time.time(),
    }))
    (tmp / _SENTINEL).write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root, keep)
    return final


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of `like`. Returns (tree, step)."""
    import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)

    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = Path(ckpt_dir) / f"step_{step}"
    data = np.load(path / "arrays.npz")
    meta = json.loads((path / "tree.json").read_text())["leaves"]
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(meta), \
        f"checkpoint has {len(meta)} leaves, model needs {len(leaves)}"
    out = []
    for i, (m, l) in enumerate(zip(meta, leaves)):
        raw = data[f"leaf_{i}"]
        arr = raw.view(np.dtype(m["dtype"])).reshape(m["shape"])
        out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = []
    for p in root.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") \
                and (p / _SENTINEL).exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def _gc(root: Path, keep: int):
    steps = sorted(int(p.name.split("_")[1]) for p in root.iterdir()
                   if p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(root / f"step_{s}", ignore_errors=True)


class AsyncCheckpointer:
    """One in-flight async save at a time (blocks if the previous one is
    still writing — same semantics as orbax's async checkpointer)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any):
        self.wait()
        # materialize to host memory synchronously (cheap) so training can
        # mutate device buffers while the thread serializes
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _work():
            save(self.ckpt_dir, step, host_tree, keep=self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
