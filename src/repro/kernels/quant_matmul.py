"""Pallas TPU kernel: quantized matmul (HAQ's serving-time runtime, §4).

Variants:
  * W8A16 — int8 weights dequantized in VMEM, bf16 MXU matmul;
  * W4A16 — int4 weights (two per byte) unpacked in VMEM: HALVES the HBM
    weight stream, which is what moves the memory roofline term for decode;
  * W8A8  — int8 x int8 -> int32 MXU accumulate, rescale on the way out
    (TPU v5e's 394 TOPS int8 path).

Blocking: grid (M/bm, N/bn, K/bk) with a VMEM fp32/int32 accumulator scratch;
K is the innermost (sequential) grid axis so the accumulator tile stays
resident across the K loop. Block shapes default to MXU-aligned
(128, 128, 256)-ish tiles and are swept in the tests.

Validated in interpret mode against kernels/ref.py on CPU; on TPU the same
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


# ------------------------------------------------------------- W8A16 ----
def _w8a16_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(F32)                       # (bm, bk)
    w = w_ref[...].astype(F32)                       # (bk, bn) int8 -> f32
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=F32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        scale = s_ref[...].astype(F32)               # (1, bn)
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_w8a16(x, w_q, scale, *, bm=128, bn=128, bk=256,
                       interpret=False):
    """x (M,K) bf16/f32, w_q (K,N) int8, scale (N,) f32 -> (M,N) x.dtype."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and scale.shape == (N,)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w8a16_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(x, w_q, scale[None, :])


# ------------------------------------------------------------- W4A16 ----
def _w4a16_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(F32)                       # (bm, bk)
    packed = wp_ref[...]                             # (bk//2, bn) int8
    lo = ((packed << 4) >> 4).astype(F32)            # sign-extended low nibble
    hi = (packed >> 4).astype(F32)
    bk2, bn = packed.shape
    # interleave back to (bk, bn): even rows lo, odd rows hi
    w = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=F32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(F32)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_w4a16(x, w_packed, scale, *, bm=128, bn=128, bk=256,
                       interpret=False):
    """x (M,K), w_packed (K//2,N) int8 (two int4 per byte along K),
    scale (N,) -> (M,N)."""
    M, K = x.shape
    Kp, N = w_packed.shape
    assert K == 2 * Kp and scale.shape == (N,)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % 2 == 0 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w4a16_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(x, w_packed, scale[None, :])


# -------------------------------------------------------------- W8A8 ----
def _w8a8_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        rescale = xs_ref[0, 0].astype(F32) * ws_ref[...].astype(F32)
        o_ref[...] = (acc_ref[...].astype(F32) * rescale).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret",
                                    "out_dtype"))
def quant_matmul_w8a8(x_q, x_scale, w_q, w_scale, *, bm=128, bn=128, bk=256,
                      out_dtype=jnp.bfloat16, interpret=False):
    """x_q (M,K) int8, x_scale () f32, w_q (K,N) int8, w_scale (N,) f32."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w8a8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale[None, None], w_scale[None, :])
