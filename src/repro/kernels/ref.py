"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These define the semantics; the kernels must match them on every
shape/dtype sweep in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


# --------------------------------------------------------- quant matmul ----
def quantize_w8(w: jax.Array):
    """Per-output-channel symmetric int8. Returns (q int8 (K,N), scale (N,))."""
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=0)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def quantize_w4_packed(w: jax.Array):
    """Per-channel symmetric int4, two values packed per int8 along K.
    Returns (packed int8 (K//2, N), scale (N,))."""
    K = w.shape[0]
    assert K % 2 == 0, K
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=0)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -7, 7).astype(jnp.int8)
    lo = q[0::2] & 0x0F
    hi = (q[1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8), scale.astype(F32)


def unpack_w4(packed: jax.Array) -> jax.Array:
    """Inverse of the int4 packing: (K//2, N) int8 -> (K, N) int8 in [-7,7]."""
    lo = packed.astype(jnp.int8) << 4
    lo = lo >> 4                     # arithmetic shift sign-extends
    hi = packed.astype(jnp.int8) >> 4
    K2, N = packed.shape
    out = jnp.zeros((K2 * 2, N), jnp.int8)
    out = out.at[0::2].set(lo)
    out = out.at[1::2].set(hi)
    return out


def quantize_a8(x: jax.Array):
    """Per-tensor symmetric int8 activations. Returns (q int8, scale ())."""
    amax = jnp.max(jnp.abs(x.astype(F32))) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def quant_matmul_w8a16(x: jax.Array, w_q: jax.Array, scale: jax.Array):
    """x (M,K) bf16/f32, w_q (K,N) int8, scale (N,) -> (M,N) x.dtype."""
    out = jnp.einsum("mk,kn->mn", x.astype(F32), w_q.astype(F32))
    return (out * scale[None, :]).astype(x.dtype)


def quant_matmul_w4a16(x: jax.Array, packed: jax.Array, scale: jax.Array):
    return quant_matmul_w8a16(x, unpack_w4(packed), scale)


def quant_matmul_w8a8(x_q: jax.Array, x_scale: jax.Array, w_q: jax.Array,
                      w_scale: jax.Array, out_dtype=jnp.bfloat16):
    """int8 x int8 -> int32 accumulate -> rescale (the int8 MXU path)."""
    acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.int32),
                     w_q.astype(jnp.int32))
    return (acc.astype(F32) * x_scale * w_scale[None, :]).astype(out_dtype)


# ----------------------------------------------------- KV-cache quant ------
def kv_qmax(bits: int) -> float:
    """Symmetric integer range for a KV bitwidth (int8 -> 127, int4 -> 7)."""
    if bits not in (4, 8):
        raise ValueError(f"KV cache bits must be 4 or 8, got {bits}")
    return 2.0 ** (bits - 1) - 1.0


def pack_int4_hd(q: jax.Array) -> jax.Array:
    """Pack int4 codes two-per-byte along head_dim (the minor axis):
    element 2i rides the low nibble, 2i+1 the high nibble.
    (..., hd) int8 in [-7, 7] -> (..., hd//2) int8."""
    assert q.shape[-1] % 2 == 0, q.shape
    lo = q[..., 0::2] & 0x0F
    hi = (q[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4_hd(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4_hd: (..., hd//2) int8 -> (..., hd) int8 in
    [-7, 7] (arithmetic shifts sign-extend the nibbles)."""
    lo = (packed.astype(jnp.int8) << 4) >> 4
    hi = packed.astype(jnp.int8) >> 4
    out = jnp.stack([lo, hi], axis=-1)            # (..., hd//2, 2)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def quantize_kv(x: jax.Array, bits: int, *, granularity: str = "token"):
    """Symmetric per-head KV quantization (the pool-write semantics).

    x (..., K, hd) — any number of leading axes; for ``granularity="page"``
    the third-from-last axis is the page-slot axis.

    granularity:
      "token" — one scale per (leading..., K): amax over hd only. This is
                what the paged pool stores (each page carries a
                (page_size, K) fp32 scale tile), because decode writes one
                token at a time and must never re-scale a page in place.
      "page"  — one scale per (page, K) pair: amax over (slot, hd). Coarser;
                kept for the scale-granularity error-bound study
                (tests/test_kvquant.py) and offline pool conversion.

    Returns (stored, scale): stored int8, packed along hd when bits == 4;
    scale fp32 with the reduced axes dropped ("token" -> x.shape[:-1],
    "page" -> x.shape[:-3] + (K,))."""
    qmax = kv_qmax(bits)
    xf = x.astype(F32)
    if granularity == "token":
        amax = jnp.max(jnp.abs(xf), axis=-1)                 # (..., K)
        scale = amax / qmax + 1e-12
        div = scale[..., None]
    elif granularity == "page":
        amax = jnp.max(jnp.abs(xf), axis=(-3, -1))           # (..., K)
        scale = amax / qmax + 1e-12
        div = scale[..., None, :, None]
    else:
        raise ValueError(f"unknown scale granularity {granularity!r}")
    q = jnp.clip(jnp.round(xf / div), -qmax, qmax).astype(jnp.int8)
    if bits == 4:
        q = pack_int4_hd(q)
    return q, scale.astype(F32)


def dequantize_kv(stored: jax.Array, scale: jax.Array, bits: int, *,
                  granularity: str = "token") -> jax.Array:
    """Inverse of quantize_kv -> f32. Exact inverse of the storage mapping;
    |x - dequantize_kv(*quantize_kv(x, bits))| <= scale/2 elementwise."""
    q = unpack_int4_hd(stored) if bits == 4 else stored
    if granularity == "token":
        return q.astype(F32) * scale[..., None]
    if granularity == "page":
        return q.astype(F32) * scale[..., None, :, None]
    raise ValueError(f"unknown scale granularity {granularity!r}")


def kv_bits_of(stored: jax.Array, hd: int) -> int:
    """Infer the stored KV bitwidth from the minor-axis size (int4 packs two
    codes per byte along hd, so the shape itself encodes the bitwidth —
    static under tracing)."""
    if stored.shape[-1] == hd:
        return 8
    if stored.shape[-1] * 2 == hd:
        return 4
    raise ValueError(
        f"stored KV minor dim {stored.shape[-1]} matches neither int8 ({hd}) "
        f"nor packed int4 ({hd // 2})")


# ------------------------------------------------------ paged attention ----
def _paged_block_walk(q, load_k, load_v, K, hd, page, n_blocks, positions, *,
                      window, cap):
    """Shared block-walk body for the fp and quantized pure-JAX paged
    attention refs — the semantics both must agree on exactly, kept in one
    place (the Pallas twins share _block_update the same way). ``load_k``/
    ``load_v`` map a block index to its fp32 (B, page, K, hd) tile — a pool
    gather for the fp path, gather + dequant for the quantized one.

    q is (B, Sq, H, hd): Sq == 1 is the decode walk, Sq > 1 the
    chunked-prefill walk — query t of sequence b sits at absolute position
    ``positions[b] + t`` and attends causally to every pool slot at or
    before it (the resident prompt prefix plus the chunk's own already-
    written K/V).

    Walks `lax.fori_loop` over the data-dependent block range —
    ``[min(first qpos) - window + 1, max(last qpos)]`` across the batch —
    so the dense chronological (B, n_blocks*page, K, hd) KV view is never
    built and local-window layers do window-trimmed walks instead of
    full-length masking. Scores are staged per-block into a (B,K,G,Sq,T)
    fp32 buffer so the softmax itself is a single full-row pass, matching
    the dense path's normalization exactly."""
    B, Sq, H, _ = q.shape
    G = H // K
    T = n_blocks * page
    scale = hd ** -0.5
    NEG = -2.0 ** 30
    # (B, Sq, K, G, hd) -> (B, K, G, Sq, hd): head h = k*G + g, matching the
    # decode reshape convention.
    qf = jnp.moveaxis(q.astype(F32).reshape(B, Sq, K, G, hd), 1, 3)
    qpos = positions[:, None] + jnp.arange(Sq, dtype=jnp.int32)  # (B, Sq)

    # blocks any query needs; a final chunk padded past the page-table
    # width must not walk past it (the overrun blocks hold only padding
    # queries, which are garbage by contract) — without the clamp the
    # staging offset saturates at T-page and clobbers the last real
    # block's scores.
    hi = jnp.minimum((jnp.max(positions) + Sq - 1) // page + 1, n_blocks)
    if window:
        lo = jnp.maximum((jnp.min(positions) - window + 1) // page, 0)
    else:
        lo = jnp.zeros((), jnp.int32)

    def score_block(i, s_buf):
        s = jnp.einsum("bkgsd,bpkd->bkgsp", qf, load_k(i)) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = i * page + jnp.arange(page)
        valid = kpos[None, None, :] <= qpos[:, :, None]          # (B, Sq, p)
        if window:
            valid &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(valid[:, None, None], s, NEG)
        return jax.lax.dynamic_update_slice(s_buf, s, (0, 0, 0, 0, i * page))

    s_buf = jnp.full((B, K, G, Sq, T), NEG, F32)
    s_buf = jax.lax.fori_loop(lo, hi, score_block, s_buf)
    w = jax.nn.softmax(s_buf, axis=-1)

    def pv_block(i, acc):
        wb = jax.lax.dynamic_slice(w, (0, 0, 0, 0, i * page),
                                   (B, K, G, Sq, page))
        return acc + jnp.einsum("bkgsp,bpkd->bkgsd", wb, load_v(i))

    o = jax.lax.fori_loop(lo, hi, pv_block,
                          jnp.zeros((B, K, G, Sq, hd), F32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def paged_attention_ref(q, pool_k, pool_v, page_table, positions, *,
                        window=0, cap=0.0):
    """Block-walking paged decode attention (the CPU serving fallback and
    the semantics oracle for kernels/paged_attention.py).

    q (B, H, hd) one query token per sequence; pool_k/v (P, page, K, hd);
    page_table (B, n_blocks) int32, unused tails pointing at scratch page 0;
    positions (B,) int32 absolute position of the query token (== index of
    the newest cached token). H = K*G (GQA). Walk semantics in
    _paged_block_walk."""
    return paged_prefill_ref(q[:, None], pool_k, pool_v, page_table,
                             positions, window=window, cap=cap)[:, 0]


def paged_prefill_ref(q, pool_k, pool_v, page_table, positions, *,
                      window=0, cap=0.0):
    """Block-walking chunked-prefill attention (the CPU serving fallback and
    the semantics oracle for paged_prefill_fwd).

    q (B, Sq, H, hd) one prompt chunk per sequence, whose K/V have already
    been written into the pool; pool_k/v (P, page, K, hd); page_table
    (B, n_blocks) int32 with unused tails on scratch page 0; positions (B,)
    int32 absolute position of each chunk's FIRST token (the resident
    prefix length). Query t attends causally to pool slots at
    kpos <= positions[b] + t — the prompt prefix resident in the pool plus
    the chunk itself. Walk semantics in _paged_block_walk."""
    hd = q.shape[-1]
    _, page, K, _ = pool_k.shape
    return _paged_block_walk(
        q, lambda i: pool_k[page_table[:, i]].astype(F32),
        lambda i: pool_v[page_table[:, i]].astype(F32),
        K, hd, page, page_table.shape[1], positions, window=window, cap=cap)


def paged_attention_dense_ref(q, pool_k, pool_v, page_table, positions, *,
                              window=0, cap=0.0):
    """Dense oracle: gather pages chronologically, mask, softmax. Test-only —
    this materializes exactly the (B, T, K, hd) view the kernel exists to
    avoid."""
    B, H, hd = q.shape
    K = pool_k.shape[2]
    k = pool_k[page_table].reshape(B, -1, K, hd)
    v = pool_v[page_table].reshape(B, -1, K, hd)
    T = k.shape[1]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    j = jnp.arange(T)[None, :]
    valid = j <= positions[:, None]
    if window:
        valid &= j > positions[:, None] - window
    s = jnp.where(valid[:, None, :], s, -2.0 ** 30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", w, v.astype(F32))
    return out.astype(q.dtype)


def paged_prefill_dense_ref(q, pool_k, pool_v, page_table, positions, *,
                            window=0, cap=0.0):
    """Dense chunked-prefill oracle: gather pages chronologically, mask each
    chunk query causally at its absolute position, softmax. Test-only —
    materializes exactly the (B, T, K, hd) view the prefill walk avoids.
    q (B, Sq, H, hd); positions (B,) chunk-start positions."""
    B, Sq, H, hd = q.shape
    K = pool_k.shape[2]
    k = pool_k[page_table].reshape(B, -1, K, hd)
    v = pool_v[page_table].reshape(B, -1, K, hd)
    T = k.shape[1]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bkhd->bhsk", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = positions[:, None] + jnp.arange(Sq)[None, :]          # (B, Sq)
    j = jnp.arange(T)
    valid = j[None, None, :] <= qpos[:, :, None]                 # (B, Sq, T)
    if window:
        valid &= j[None, None, :] > qpos[:, :, None] - window
    s = jnp.where(valid[:, None], s, -2.0 ** 30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhsk,bkhd->bshd", w, v.astype(F32))
    return out.astype(q.dtype)


def paged_attention_quant_ref(q, pool_k, k_scale, pool_v, v_scale,
                              page_table, positions, *, window=0, cap=0.0):
    """Block-walking paged decode attention over a *quantized* page pool
    (the CPU serving fallback and the semantics oracle for the fused-dequant
    Pallas kernel).

    q (B, H, hd) fp; pool_k/v (P, page, K, hd_store) int8 — hd_store == hd
    for int8 KV, hd // 2 for int4 packed along head_dim (pack_int4_hd);
    k_scale/v_scale (P, page, K) fp32 per-page-slot, per-kv-head scales;
    page_table (B, n_blocks) int32 with unused tails on scratch page 0;
    positions (B,) int32.

    Pages are dequantized one block at a time inside the walk — each block
    materializes only a (B, page, K, hd) fp tile; the dense chronological
    (B, n_blocks*page, K, hd) fp KV view is never built (asserted on the
    decode jaxpr in tests/test_kvquant.py). Walk semantics shared with the
    fp ref via _paged_block_walk."""
    return paged_prefill_quant_ref(q[:, None], pool_k, k_scale, pool_v,
                                   v_scale, page_table, positions,
                                   window=window, cap=cap)[:, 0]


def paged_prefill_quant_ref(q, pool_k, k_scale, pool_v, v_scale,
                            page_table, positions, *, window=0, cap=0.0):
    """Chunked-prefill walk over a *quantized* page pool: the chunk's K/V
    are already quantized into the pool, and each block is dequantized
    inside the walk exactly as in paged_attention_quant_ref. q (B, Sq, H,
    hd) fp; positions (B,) int32 chunk-start positions (see
    paged_prefill_ref)."""
    hd = q.shape[-1]
    _, page, K, _ = pool_k.shape
    bits = kv_bits_of(pool_k, hd)

    def loader(pool, scales):
        def load(i):
            pids = page_table[:, i]
            return dequantize_kv(pool[pids], scales[pids], bits)
        return load

    return _paged_block_walk(
        q, loader(pool_k, k_scale), loader(pool_v, v_scale),
        K, hd, page, page_table.shape[1], positions, window=window, cap=cap)


# ------------------------------------------------------ flash attention ----
def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """Dense attention oracle. q (B,S,H,hd), k/v (B,T,K,hd) GQA."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(F32))
    return out.astype(q.dtype)
