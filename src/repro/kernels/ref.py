"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These define the semantics; the kernels must match them on every
shape/dtype sweep in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


# --------------------------------------------------------- quant matmul ----
def quantize_w8(w: jax.Array):
    """Per-output-channel symmetric int8. Returns (q int8 (K,N), scale (N,))."""
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=0)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def quantize_w4_packed(w: jax.Array):
    """Per-channel symmetric int4, two values packed per int8 along K.
    Returns (packed int8 (K//2, N), scale (N,))."""
    K = w.shape[0]
    assert K % 2 == 0, K
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=0)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -7, 7).astype(jnp.int8)
    lo = q[0::2] & 0x0F
    hi = (q[1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8), scale.astype(F32)


def unpack_w4(packed: jax.Array) -> jax.Array:
    """Inverse of the int4 packing: (K//2, N) int8 -> (K, N) int8 in [-7,7]."""
    lo = packed.astype(jnp.int8) << 4
    lo = lo >> 4                     # arithmetic shift sign-extends
    hi = packed.astype(jnp.int8) >> 4
    K2, N = packed.shape
    out = jnp.zeros((K2 * 2, N), jnp.int8)
    out = out.at[0::2].set(lo)
    out = out.at[1::2].set(hi)
    return out


def quantize_a8(x: jax.Array):
    """Per-tensor symmetric int8 activations. Returns (q int8, scale ())."""
    amax = jnp.max(jnp.abs(x.astype(F32))) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def quant_matmul_w8a16(x: jax.Array, w_q: jax.Array, scale: jax.Array):
    """x (M,K) bf16/f32, w_q (K,N) int8, scale (N,) -> (M,N) x.dtype."""
    out = jnp.einsum("mk,kn->mn", x.astype(F32), w_q.astype(F32))
    return (out * scale[None, :]).astype(x.dtype)


def quant_matmul_w4a16(x: jax.Array, packed: jax.Array, scale: jax.Array):
    return quant_matmul_w8a16(x, unpack_w4(packed), scale)


def quant_matmul_w8a8(x_q: jax.Array, x_scale: jax.Array, w_q: jax.Array,
                      w_scale: jax.Array, out_dtype=jnp.bfloat16):
    """int8 x int8 -> int32 accumulate -> rescale (the int8 MXU path)."""
    acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.int32),
                     w_q.astype(jnp.int32))
    return (acc.astype(F32) * x_scale * w_scale[None, :]).astype(out_dtype)


# ------------------------------------------------------ flash attention ----
def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """Dense attention oracle. q (B,S,H,hd), k/v (B,T,K,hd) GQA."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(F32))
    return out.astype(q.dtype)
