"""Pallas TPU kernel: flash attention forward (serving prefill hot-spot).

Grid: (batch*kv_heads, q_blocks); each program owns one (b, kv-head, q-block)
tile and loops over kv blocks with fp32 (m, l, acc) VMEM scratch. GQA is
handled by processing all G query heads of the kv-head group in one tile
(q tile shape (G*bq, hd)) so the kv block is loaded from HBM once per group —
the bandwidth win GQA exists for.

Causal blocks beyond the diagonal are skipped via the kv-block upper bound
(true compute skipping, unlike the XLA twin in models/flash.py which masks).
Local windows additionally bound the kv range from below.

Forward-only by design: training runs the XLA twin (custom VJP); this kernel
is the serving path. Validated in interpret mode vs kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bkv, seq_kv, G, hd,
                  causal, window, cap, scale):
    # q_ref: (G*bq, hd) one q-block for all G heads of this kv group
    # k_ref/v_ref: (seq_kv, hd) the full kv stream of this group (VMEM-
    #              resident per program; fine at serving block sizes)
    qi = pl.program_id(1)
    q = q_ref[...].reshape(G * bq, hd).astype(F32) * scale

    n_kv = seq_kv // bkv
    if causal:
        # kv blocks strictly above the diagonal contribute nothing
        hi = jnp.minimum(((qi + 1) * bq + bkv - 1) // bkv, n_kv)
    else:
        hi = n_kv
    lo = 0
    if window:
        lo = jnp.maximum((qi * bq - window) // bkv, 0)

    k_all = k_ref[...].reshape(seq_kv, hd)
    v_all = v_ref[...].reshape(seq_kv, hd)

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_all, (j * bkv, 0), (bkv, hd)) \
            .astype(F32)
        v = jax.lax.dynamic_slice(v_all, (j * bkv, 0), (bkv, hd)) \
            .astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (G*bq, bkv)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bkv),
                                                  0) % bq
        # NOTE: iota over the fused (G, bq) rows: row r belongs to q position
        # qi*bq + r % bq (heads share positions)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bkv), 1)
        valid = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            valid &= kpos <= qpos
        if window:
            valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG)
        new_m = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - new_m)
        p = jnp.exp(s - new_m[:, None])
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
        return m * 0 + new_m, l, acc

    m0 = jnp.full((G * bq,), NEG, F32)
    l0 = jnp.zeros((G * bq,), F32)
    a0 = jnp.zeros((G * bq, hd), F32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bkv", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0, cap=0.0,
                        bq=256, bkv=256, interpret=False):
    """q (B,S,H,hd), k/v (B,T,K,hd) -> (B,S,H,hd). H = K*G."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, S)
    bkv = min(bkv, T)
    assert S % bq == 0 and T % bkv == 0
    scale = hd ** -0.5

    # layout: fold (B, K) into the grid; q rows (G, bq) fused per tile
    qr = q.reshape(B, S, K, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * K, G, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)

    kernel = functools.partial(_flash_kernel, bq=bq, bkv=bkv, seq_kv=T,
                               G=G, hd=hd, causal=causal, window=window,
                               cap=cap, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, S // bq),
        in_specs=[
            pl.BlockSpec((1, G, bq, hd), lambda g, i: (g, 0, i, 0)),
            pl.BlockSpec((1, T, hd), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, T, hd), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, bq, hd), lambda g, i: (g, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, S, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, H, hd)
