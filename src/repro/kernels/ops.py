"""Jit'd public wrappers around the Pallas kernels.

Handle layout (rank-3 activations, padding to block multiples), backend
dispatch (interpret=True off-TPU so CPU tests execute the kernel body), and
the weight-quantization caching used by the serving path.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import paged_attention as pa
from repro.kernels import quant_matmul as qmm
from repro.kernels import ref

F32 = jnp.float32


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def quant_matmul(x: jax.Array, w: jax.Array, *, w_bits: int = 8,
                 a_bits: int = 16, bm: int = 128, bn: int = 128,
                 bk: int = 256) -> jax.Array:
    """Drop-in einsum('...d,df->...f') replacement with on-the-fly weight
    quantization — the HAQ `dot` hook's kernel path. For a real deployment
    the weights are quantized once via `prepare_quantized` below."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    x2, pm = _pad_to(x2, bm if x2.shape[0] >= bm else 8, 0)
    bm_eff = min(bm, x2.shape[0])
    interp = _interpret()
    if w_bits <= 4:
        packed, scale = ref.quantize_w4_packed(w)
        out = qmm.quant_matmul_w4a16(x2, packed, scale, bm=bm_eff, bn=bn,
                                     bk=bk, interpret=interp)
    elif a_bits <= 8:
        wq, ws = ref.quantize_w8(w)
        xq, xs = ref.quantize_a8(x2)
        out = qmm.quant_matmul_w8a8(xq, xs, wq, ws, bm=bm_eff, bn=bn,
                                    bk=bk, out_dtype=x.dtype,
                                    interpret=interp)
    else:
        wq, ws = ref.quantize_w8(w)
        out = qmm.quant_matmul_w8a16(x2, wq, ws, bm=bm_eff, bn=bn, bk=bk,
                                     interpret=interp)
    if pm:
        out = out[:-pm]
    return out.reshape(*lead, N)


def prepare_quantized(w: jax.Array, w_bits: int) -> Dict[str, jax.Array]:
    """One-time weight quantization for serving (stored int side tables)."""
    if w_bits <= 4:
        packed, scale = ref.quantize_w4_packed(w)
        return {"q": packed, "scale": scale, "bits": jnp.asarray(4)}
    q, scale = ref.quantize_w8(w)
    return {"q": q, "scale": scale, "bits": jnp.asarray(8)}


def quant_matmul_prepared(x: jax.Array, qw: Dict[str, jax.Array],
                          *, a_bits: int = 16) -> jax.Array:
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x2, pm = _pad_to(x2, 8, 0)
    interp = _interpret()
    bm = min(128, x2.shape[0])
    if int(qw["bits"]) <= 4:
        out = qmm.quant_matmul_w4a16(x2, qw["q"], qw["scale"], bm=bm,
                                     interpret=interp)
    elif a_bits <= 8:
        xq, xs = ref.quantize_a8(x2)
        out = qmm.quant_matmul_w8a8(xq, xs, qw["q"], qw["scale"],
                                    bm=bm, out_dtype=x.dtype,
                                    interpret=interp)
    else:
        out = qmm.quant_matmul_w8a16(x2, qw["q"], qw["scale"], bm=bm,
                                     interpret=interp)
    if pm:
        out = out[:-pm]
    return out.reshape(*lead, -1)


def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    bq=256, bkv=256) -> jax.Array:
    """Pallas flash attention forward (serving path)."""
    return fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                  cap=cap, bq=bq, bkv=bkv,
                                  interpret=_interpret())


def _paged_mode(mode: str) -> str:
    """Resolve the paged-attention dispatch once for all four entry points:
    "auto" lowers to the Pallas page-walk kernel on TPU and the pure-JAX
    block walk elsewhere. The choice is backend-global and shape-free, so
    the same dispatch works inside shard_map-partitioned programs — the
    sharded engine (serving/engine/sharded.py) traces these walks per shard
    with a local kv-head slice of the pool."""
    if mode == "auto":
        return "ref" if _interpret() else "pallas"
    if mode not in ("ref", "pallas"):
        raise ValueError(f"unknown paged-attention mode {mode!r}")
    return mode


def paged_attention(q, pool_k, pool_v, page_table, positions, *,
                    window=0, cap=0.0, mode: str = "auto") -> jax.Array:
    """Paged-attention decode: q (B,H,hd) against the page pool.

    mode: "auto" -> Pallas kernel on TPU, pure-JAX block walk elsewhere;
    "pallas" forces the kernel (interpret mode off-TPU — slow, tests only);
    "ref" forces the block walk. Both walk pages and never materialize the
    dense chronological KV view."""
    if _paged_mode(mode) == "ref":
        return ref.paged_attention_ref(q, pool_k, pool_v, page_table,
                                       positions, window=window, cap=cap)
    return pa.paged_attention_fwd(q, pool_k, pool_v, page_table, positions,
                                  window=window, cap=cap,
                                  interpret=_interpret())


def paged_attention_quant(q, pool_k, k_scale, pool_v, v_scale, page_table,
                          positions, *, window=0, cap=0.0,
                          mode: str = "auto") -> jax.Array:
    """Paged-attention decode over a quantized KV page pool.

    pool_k/v are int8 (int4 packed along head_dim — bitwidth is inferred
    from the stored minor-dim size) with (P, page, K) fp32 scales. Same
    dispatch contract as paged_attention; every path dequantizes block-by-
    block inside the walk and never materializes a dense fp KV view."""
    if _paged_mode(mode) == "ref":
        return ref.paged_attention_quant_ref(
            q, pool_k, k_scale, pool_v, v_scale, page_table, positions,
            window=window, cap=cap)
    return pa.paged_attention_quant_fwd(
        q, pool_k, k_scale, pool_v, v_scale, page_table, positions,
        window=window, cap=cap, interpret=_interpret())


def paged_attention_prefill(q, pool_k, pool_v, page_table, positions, *,
                            window=0, cap=0.0, mode: str = "auto"):
    """Chunked-prefill attention: q (B, Sq, H, hd) — one prompt chunk per
    sequence whose K/V are already resident in the pool — against the page
    pool, causal at each query's absolute position (``positions`` holds the
    chunk-start offsets). Same dispatch contract as paged_attention; both
    paths walk pages and never materialize the dense prompt KV view."""
    if _paged_mode(mode) == "ref":
        return ref.paged_prefill_ref(q, pool_k, pool_v, page_table,
                                     positions, window=window, cap=cap)
    return pa.paged_prefill_fwd(q, pool_k, pool_v, page_table, positions,
                                window=window, cap=cap,
                                interpret=_interpret())


def paged_attention_prefill_quant(q, pool_k, k_scale, pool_v, v_scale,
                                  page_table, positions, *, window=0,
                                  cap=0.0, mode: str = "auto"):
    """Chunked-prefill attention over a quantized KV page pool (the chunk's
    K/V are already quantized on write); dequantization happens block-by-
    block inside the walk on every path."""
    if _paged_mode(mode) == "ref":
        return ref.paged_prefill_quant_ref(
            q, pool_k, k_scale, pool_v, v_scale, page_table, positions,
            window=window, cap=cap)
    return pa.paged_prefill_quant_fwd(
        q, pool_k, k_scale, pool_v, v_scale, page_table, positions,
        window=window, cap=cap, interpret=_interpret())
