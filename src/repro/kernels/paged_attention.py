"""Pallas TPU kernel: paged-attention decode (serving decode hot-spot).

One query token per sequence attends to its KV history stored in a paged
pool — the kernel walks ``page_table[b]`` block-by-block with an online
softmax (flash-style running max/sum, like kernels/flash_attention.py),
fusing the page gather, the causal/local-window mask, and the attention
itself, so the dense chronological ``(B, n_blocks*page, K, hd)`` KV view is
never materialized in HBM.

Layout: the pool keeps its serving layout ``(num_pages, page, K, hd)``;
``page_table``/``positions`` ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``) so the kv BlockSpec index map can resolve
logical block ``i`` of sequence ``b`` to physical page ``page_table[b, i]``
before the DMA is issued. Grid is ``(B, K, n_blocks)`` — the block axis is
innermost, so the fp32 (m, l, acc) VMEM scratch carries across a sequence's
page walk and the output tile is written once on the final block.

Blocks a sequence does not need — past ``positions[b]`` or, for local
layers, wholly below the window — are skipped: the index map clamps their
page id onto an already-resident page (no new copy is pipelined in) and
``pl.when`` predication skips the FLOPs. That makes local-window walks
O(window), not O(T) — the roofline win the admission policy already
assumes.

Forward-only by design (decode). Validated against the dense oracle in
tests/test_kernels.py (interpret mode); the pure-JAX block-walk twin used
as the CPU fallback lives in kernels/ref.py::paged_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30
LANES = 128  # scratch minor dim, aligned to the VPU lane width


def _paged_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page, G, hd, window, cap, scale,
                  n_blocks):
    # q_ref: (1, 1, G, hd) the G query heads of this (batch, kv-head) pair
    # k_ref/v_ref: (1, page, 1, hd) one physical page of this kv head
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    hi = pos // page                       # last block holding a live token
    if window:
        lo = jnp.maximum((pos - window + 1) // page, 0)
    else:
        lo = 0

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i >= lo) & (i <= hi))
    def _block():
        q = q_ref[...].reshape(G, hd).astype(F32) * scale
        k = k_ref[...].reshape(page, hd).astype(F32)
        v = v_ref[...].reshape(page, hd).astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)    # (G, page)
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = i * page + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        valid = kpos <= pos
        if window:
            valid &= kpos > pos - window
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[:, :1]                                  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = l_ref[...] * corr \
            + jnp.broadcast_to(jnp.sum(p, axis=-1, keepdims=True),
                               l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def paged_attention_fwd(q, pool_k, pool_v, page_table, positions, *,
                        window=0, cap=0.0, interpret=False):
    """q (B,H,hd); pool_k/v (P, page, K, hd); page_table (B, n_blocks) int32
    (unused tails -> scratch page 0); positions (B,) int32. H = K*G.
    Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, page, K, _ = pool_k.shape
    G = H // K
    n_blocks = page_table.shape[1]
    scale = hd ** -0.5
    qr = q.reshape(B, K, G, hd)

    kernel = functools.partial(_paged_kernel, page=page, G=G, hd=hd,
                               window=window, cap=cap, scale=scale,
                               n_blocks=n_blocks)

    def kv_map(b, k, i, pt, pos):
        # clamp skipped blocks onto an in-range (already fetched) page so no
        # fresh DMA is pipelined for them; pl.when skips their compute.
        p = pos[b]
        hi = p // page
        if window:
            lo = jnp.maximum((p - window + 1) // page, 0)
            ic = jnp.clip(i, lo, hi)
        else:
            ic = jnp.minimum(i, hi)
        return (pt[b, ic], 0, k, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, i, pt, pos: (b, k, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, i, pt, pos: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), F32),    # running max m
            pltpu.VMEM((G, LANES), F32),    # running sum l
            pltpu.VMEM((G, hd), F32),       # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, positions, qr, pool_k, pool_v)
    return out.reshape(B, H, hd)
