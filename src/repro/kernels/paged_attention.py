"""Pallas TPU kernel: paged-attention decode (serving decode hot-spot).

One query token per sequence attends to its KV history stored in a paged
pool — the kernel walks ``page_table[b]`` block-by-block with an online
softmax (flash-style running max/sum, like kernels/flash_attention.py),
fusing the page gather, the causal/local-window mask, and the attention
itself, so the dense chronological ``(B, n_blocks*page, K, hd)`` KV view is
never materialized in HBM.

Layout: the pool keeps its serving layout ``(num_pages, page, K, hd)``;
``page_table``/``positions`` ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``) so the kv BlockSpec index map can resolve
logical block ``i`` of sequence ``b`` to physical page ``page_table[b, i]``
before the DMA is issued. Grid is ``(B, K, n_blocks)`` — the block axis is
innermost, so the fp32 (m, l, acc) VMEM scratch carries across a sequence's
page walk and the output tile is written once on the final block.

Blocks a sequence does not need — past ``positions[b]`` or, for local
layers, wholly below the window — are skipped: the index map clamps their
page id onto an already-resident page (no new copy is pipelined in) and
``pl.when`` predication skips the FLOPs. That makes local-window walks
O(window), not O(T) — the roofline win the admission policy already
assumes.

Forward-only by design (decode). Validated against the dense oracle in
tests/test_kernels.py (interpret mode); the pure-JAX block-walk twin used
as the CPU fallback lives in kernels/ref.py::paged_attention_ref.

``paged_attention_quant_fwd`` is the fused-dequant variant for the HAQ
KV-quantized page pool (serving/kvquant): pages arrive int8 (int4 packed
two-per-byte along head_dim) with per-page-slot per-head fp32 scale tiles
that ride the same scalar-prefetched page-table walk, and dequantization
happens inside the online-softmax block loop — one (page, hd) fp tile in
VMEM at a time, never a dense fp KV view in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

F32 = jnp.float32
NEG = -1e30
LANES = 128  # scratch minor dim, aligned to the VPU lane width


def _block_update(q, k, v, pos, i, *, page, window, cap,
                  m_ref, l_ref, acc_ref):
    """Masked online-softmax accumulation of one fp32 (page, hd) KV block —
    the math the fp/fused-dequant decode kernels AND their chunked-prefill
    variants must agree on exactly, kept in one place. q (rows, hd)
    pre-scaled fp32; ``pos`` is the query position — a scalar for decode
    (every row is the same token's G query heads) or a (rows, 1) per-row
    vector for chunked prefill (rows = Sq*G, causal within the chunk)."""
    rows = q.shape[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)        # (rows, page)
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = i * page + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1)
    valid = kpos <= pos
    if window:
        valid &= kpos > pos - window
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[:, :1]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = l_ref[...] * corr \
        + jnp.broadcast_to(jnp.sum(p, axis=-1, keepdims=True),
                           l_ref.shape)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)


def _block_range(pos, page, window, span=1):
    """(lo, hi) inclusive block range the queries at ``pos .. pos+span-1``
    must walk (span == 1 is the decode case; chunked prefill passes the
    chunk length). ``lo`` is the first query's window start — later queries
    only look higher, and the per-row mask handles the rest."""
    hi = (pos + span - 1) // page          # last block holding a live token
    lo = jnp.maximum((pos - window + 1) // page, 0) if window else 0
    return lo, hi


def _init_scratch(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _finalize_out(o_ref, l_ref, acc_ref):
    out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
    o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def _kv_index_map(page, window, span=1):
    """Shared BlockSpec index map for the page-table walk: clamp skipped
    blocks onto an in-range (already fetched) page so no fresh DMA is
    pipelined for them; pl.when skips their compute."""
    def kv_map(b, k, i, pt, pos):
        p = pos[b]
        lo, hi = _block_range(p, page, window, span)
        ic = jnp.clip(i, lo, hi) if window else jnp.minimum(i, hi)
        return (pt[b, ic], 0, k, 0)
    return kv_map


def _paged_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page, G, hd, window, cap, scale,
                  n_blocks):
    # q_ref: (1, 1, G, hd) the G query heads of this (batch, kv-head) pair
    # k_ref/v_ref: (1, page, 1, hd) one physical page of this kv head
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    lo, hi = _block_range(pos, page, window)

    @pl.when(i == 0)
    def _init():
        _init_scratch(m_ref, l_ref, acc_ref)

    @pl.when((i >= lo) & (i <= hi))
    def _block():
        q = q_ref[...].reshape(G, hd).astype(F32) * scale
        k = k_ref[...].reshape(page, hd).astype(F32)
        v = v_ref[...].reshape(page, hd).astype(F32)
        _block_update(q, k, v, pos, i, page=page, window=window, cap=cap,
                      m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        _finalize_out(o_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def paged_attention_fwd(q, pool_k, pool_v, page_table, positions, *,
                        window=0, cap=0.0, interpret=False):
    """q (B,H,hd); pool_k/v (P, page, K, hd); page_table (B, n_blocks) int32
    (unused tails -> scratch page 0); positions (B,) int32. H = K*G.
    Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, page, K, _ = pool_k.shape
    G = H // K
    n_blocks = page_table.shape[1]
    scale = hd ** -0.5
    qr = q.reshape(B, K, G, hd)

    kernel = functools.partial(_paged_kernel, page=page, G=G, hd=hd,
                               window=window, cap=cap, scale=scale,
                               n_blocks=n_blocks)
    kv_map = _kv_index_map(page, window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, i, pt, pos: (b, k, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, i, pt, pos: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), F32),    # running max m
            pltpu.VMEM((G, LANES), F32),    # running sum l
            pltpu.VMEM((G, hd), F32),       # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, positions, qr, pool_k, pool_v)
    return out.reshape(B, H, hd)


# ------------------------------------------------- fused-dequant variant ----
def _paged_quant_kernel(pt_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                        o_ref, m_ref, l_ref, acc_ref, *, page, G, hd, bits,
                        window, cap, scale, n_blocks):
    # q_ref: (1, 1, G, hd) fp; k_ref/v_ref: (1, page, 1, hd_store) int8, one
    # physical page of this kv head; ks_ref/vs_ref: (1, page, 1) fp32 scales
    # riding the same scalar-prefetched page-table walk as the int8 pages.
    # Dequant happens here, inside the online-softmax block loop — the only
    # fp KV ever materialized is one (page, hd) tile in VMEM. Everything
    # past the load is _block_update, shared with the fp kernel.
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    lo, hi = _block_range(pos, page, window)

    @pl.when(i == 0)
    def _init():
        _init_scratch(m_ref, l_ref, acc_ref)

    @pl.when((i >= lo) & (i <= hi))
    def _block():
        q = q_ref[...].reshape(G, hd).astype(F32) * scale

        def dequant(int_ref, scale_ref):
            qv = int_ref[...].reshape(page, -1)
            if bits == 4:
                # the storage mapping's single source of truth (static
                # shapes, jnp-only — fine inside the kernel body)
                qv = ref.unpack_int4_hd(qv)
            return qv.astype(F32) * scale_ref[...].reshape(page, 1)

        _block_update(q, dequant(k_ref, ks_ref), dequant(v_ref, vs_ref),
                      pos, i, page=page, window=window, cap=cap,
                      m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        _finalize_out(o_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def paged_attention_quant_fwd(q, pool_k, k_scale, pool_v, v_scale,
                              page_table, positions, *, window=0, cap=0.0,
                              interpret=False):
    """Fused dequantizing paged-attention decode.

    q (B, H, hd) fp; pool_k/v (P, page, K, hd_store) int8 with hd_store = hd
    (int8 KV) or hd//2 (int4 packed along head_dim); k_scale/v_scale
    (P, page, K) fp32 per-page-slot per-head scales; page_table (B,
    n_blocks) int32 (unused tails -> scratch page 0); positions (B,) int32.

    The scale tiles use the same scalar-prefetch index map as their pages,
    so the page-table walk resolves both DMAs before issue; dequantization
    happens inside the online-softmax block loop and no dense fp KV view is
    ever materialized. Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, page, K, hd_store = pool_k.shape
    bits = ref.kv_bits_of(pool_k, hd)
    G = H // K
    n_blocks = page_table.shape[1]
    scale = hd ** -0.5
    qr = q.reshape(B, K, G, hd)

    kernel = functools.partial(_paged_quant_kernel, page=page, G=G, hd=hd,
                               bits=bits, window=window, cap=cap, scale=scale,
                               n_blocks=n_blocks)
    kv_map = _kv_index_map(page, window)

    def scale_map(b, k, i, pt, pos):
        return kv_map(b, k, i, pt, pos)[:3]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, i, pt, pos: (b, k, 0, 0)),
            pl.BlockSpec((1, page, 1, hd_store), kv_map),
            pl.BlockSpec((1, page, 1), scale_map),
            pl.BlockSpec((1, page, 1, hd_store), kv_map),
            pl.BlockSpec((1, page, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, i, pt, pos: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), F32),    # running max m
            pltpu.VMEM((G, LANES), F32),    # running sum l
            pltpu.VMEM((G, hd), F32),       # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, positions, qr, pool_k, k_scale, pool_v, v_scale)
    return out.reshape(B, H, hd)


# ------------------------------------------------ chunked-prefill variant ----
def _prefill_qpos(pos, Sq, G):
    """Per-row query positions for the (Sq*G, hd) flattened chunk: row
    r = s*G + g holds query s, so its absolute position is pos + r // G."""
    r = jax.lax.broadcasted_iota(jnp.int32, (Sq * G, 1), 0)
    return pos + r // G


def _paged_prefill_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, page, Sq, G, hd, window,
                          cap, scale, n_blocks):
    # q_ref: (1, 1, Sq, G, hd) — one (batch, kv-head)'s chunk of queries,
    # flattened to (Sq*G, hd) rows; k_ref/v_ref: (1, page, 1, hd) one
    # physical page of this kv head, walked exactly like decode but with a
    # per-row causal mask (query t sees kpos <= pos + t).
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    lo, hi = _block_range(pos, page, window, span=Sq)

    @pl.when(i == 0)
    def _init():
        _init_scratch(m_ref, l_ref, acc_ref)

    @pl.when((i >= lo) & (i <= hi))
    def _block():
        q = q_ref[...].reshape(Sq * G, hd).astype(F32) * scale
        k = k_ref[...].reshape(page, hd).astype(F32)
        v = v_ref[...].reshape(page, hd).astype(F32)
        _block_update(q, k, v, _prefill_qpos(pos, Sq, G), i, page=page,
                      window=window, cap=cap, m_ref=m_ref, l_ref=l_ref,
                      acc_ref=acc_ref)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        _finalize_out(o_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def paged_prefill_fwd(q, pool_k, pool_v, page_table, positions, *,
                      window=0, cap=0.0, interpret=False):
    """Chunked-prefill attention over the page pool (prefill-with-cache).

    q (B, Sq, H, hd) — one prompt chunk of queries per sequence, whose K/V
    have already been scattered into the pool; pool_k/v (P, page, K, hd);
    page_table (B, n_blocks) int32 (unused tails -> scratch page 0);
    positions (B,) int32 absolute position of each chunk's FIRST token.
    Query t of sequence b attends causally to kpos <= positions[b] + t —
    the resident prompt prefix plus the chunk itself — via the same
    scalar-prefetched page-table walk and _block_update body as decode, so
    the dense (B, n_blocks*page, K, hd) prompt KV view is never
    materialized. Returns (B, Sq, H, hd) in q.dtype."""
    B, Sq, H, hd = q.shape
    _, page, K, _ = pool_k.shape
    G = H // K
    n_blocks = page_table.shape[1]
    scale = hd ** -0.5
    qr = jnp.moveaxis(q.reshape(B, Sq, K, G, hd), 1, 2)  # (B, K, Sq, G, hd)

    kernel = functools.partial(_paged_prefill_kernel, page=page, Sq=Sq, G=G,
                               hd=hd, window=window, cap=cap, scale=scale,
                               n_blocks=n_blocks)
    kv_map = _kv_index_map(page, window, span=Sq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, Sq, G, hd),
                         lambda b, k, i, pt, pos: (b, k, 0, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Sq, G, hd),
                               lambda b, k, i, pt, pos: (b, k, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sq * G, LANES), F32),    # running max m
            pltpu.VMEM((Sq * G, LANES), F32),    # running sum l
            pltpu.VMEM((Sq * G, hd), F32),       # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Sq, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, positions, qr, pool_k, pool_v)
    return jnp.moveaxis(out, 2, 1).reshape(B, Sq, H, hd)


def _paged_prefill_quant_kernel(pt_ref, pos_ref, q_ref, k_ref, ks_ref,
                                v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref,
                                *, page, Sq, G, hd, bits, window, cap, scale,
                                n_blocks):
    # The fused-dequant chunked-prefill walk: int8/int4 pages + scale tiles
    # ride the scalar-prefetched page-table walk (as in the decode quant
    # kernel); the per-row causal chunk mask comes from _prefill_qpos.
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    lo, hi = _block_range(pos, page, window, span=Sq)

    @pl.when(i == 0)
    def _init():
        _init_scratch(m_ref, l_ref, acc_ref)

    @pl.when((i >= lo) & (i <= hi))
    def _block():
        q = q_ref[...].reshape(Sq * G, hd).astype(F32) * scale

        def dequant(int_ref, scale_ref):
            qv = int_ref[...].reshape(page, -1)
            if bits == 4:
                qv = ref.unpack_int4_hd(qv)
            return qv.astype(F32) * scale_ref[...].reshape(page, 1)

        _block_update(q, dequant(k_ref, ks_ref), dequant(v_ref, vs_ref),
                      _prefill_qpos(pos, Sq, G), i, page=page, window=window,
                      cap=cap, m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        _finalize_out(o_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def paged_prefill_quant_fwd(q, pool_k, k_scale, pool_v, v_scale,
                            page_table, positions, *, window=0, cap=0.0,
                            interpret=False):
    """Fused-dequant chunked-prefill attention over a quantized page pool.

    q (B, Sq, H, hd) fp chunk queries (K/V already quantized into the
    pool); pool_k/v (P, page, K, hd_store) int8 with hd_store = hd (int8)
    or hd//2 (int4 packed along head_dim); k_scale/v_scale (P, page, K)
    fp32; page_table (B, n_blocks); positions (B,) chunk-start positions.
    Same walk as paged_prefill_fwd with dequantization inside the block
    loop. Returns (B, Sq, H, hd) in q.dtype."""
    B, Sq, H, hd = q.shape
    _, page, K, hd_store = pool_k.shape
    bits = ref.kv_bits_of(pool_k, hd)
    G = H // K
    n_blocks = page_table.shape[1]
    scale = hd ** -0.5
    qr = jnp.moveaxis(q.reshape(B, Sq, K, G, hd), 1, 2)  # (B, K, Sq, G, hd)

    kernel = functools.partial(_paged_prefill_quant_kernel, page=page, Sq=Sq,
                               G=G, hd=hd, bits=bits, window=window, cap=cap,
                               scale=scale, n_blocks=n_blocks)
    kv_map = _kv_index_map(page, window, span=Sq)

    def scale_map(b, k, i, pt, pos):
        return kv_map(b, k, i, pt, pos)[:3]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, Sq, G, hd),
                         lambda b, k, i, pt, pos: (b, k, 0, 0, 0)),
            pl.BlockSpec((1, page, 1, hd_store), kv_map),
            pl.BlockSpec((1, page, 1), scale_map),
            pl.BlockSpec((1, page, 1, hd_store), kv_map),
            pl.BlockSpec((1, page, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Sq, G, hd),
                               lambda b, k, i, pt, pos: (b, k, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sq * G, LANES), F32),    # running max m
            pltpu.VMEM((Sq * G, LANES), F32),    # running sum l
            pltpu.VMEM((Sq * G, hd), F32),       # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Sq, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, positions, qr, pool_k, k_scale, pool_v, v_scale)
    return jnp.moveaxis(out, 2, 1).reshape(B, Sq, H, hd)
