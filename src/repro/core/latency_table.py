"""Per-op latency lookup table + differentiable expected latency (paper Eq. 2).

"To build the latency model we pre-compute the latency of each operator with
all possible inputs. During search we query the lookup table." — the LUT here
is precomputed from the TPU roofline simulator (hardware_model) for every
candidate op of the LM search space at the target (batch, seq) shape, per
hardware target.

E[LAT] = sum_i sum_op p_{i,op} * F(op_i)          (Eq. 2)

p = softmax(alpha) makes E[LAT] differentiable in the architecture
parameters, which is what lets the paper fold hardware latency into the
gradient-descent search loss (Eq. 3).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware_model as hwm
from repro.configs.supernet_lm import CANDIDATE_OPS


def op_latency(op: str, cfg, batch: int, seq: int, hw: hwm.Hardware,
               *, decode: bool = False) -> float:
    """Latency of one candidate block-op at the given shape (seconds)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    tokens = batch * (1 if decode else seq)
    tp = min(hw.chips, 16)

    def attn_block(window: int, e: int) -> float:
        t = 0.0
        H, K = cfg.num_heads, cfg.num_kv_heads
        t += hwm.linear_cost(tokens, d, (H + 2 * K) * hd, tp=tp).latency(hw)
        t += hwm.attention_cost(batch, 1 if decode else seq, seq, H, K, hd,
                                window=window, decode=decode).latency(hw)
        t += hwm.linear_cost(tokens, H * hd, d, tp=tp).latency(hw)
        # gated FFN at expansion e: 3 matmuls
        t += 3.0 * hwm.linear_cost(tokens, d, e * d, tp=tp).latency(hw)
        return float(t)

    if op == "zero":
        return 0.0
    if op == "mamba2_e2":
        s = cfg.ssm
        t = hwm.linear_cost(tokens, d, 2 * 2 * d, tp=tp).latency(hw)
        t += hwm.ssd_cost(batch, 1 if decode else seq, 2 * d,
                          s.d_state if s else 64,
                          s.chunk if s else 128).latency(hw)
        t += hwm.linear_cost(tokens, 2 * d, d, tp=tp).latency(hw)
        return float(t)
    table = {
        "attn_full_e2": (0, 2), "attn_full_e4": (0, 4),
        "attn_local1k_e2": (1024, 2), "attn_local1k_e4": (1024, 4),
        "attn_local4k_e4": (4096, 4),
    }
    window, e = table[op]
    return attn_block(window, e)


def build_lut(cfg, batch: int, seq: int, hw: hwm.Hardware,
              ops: Sequence[str] = CANDIDATE_OPS, *,
              decode: bool = False) -> jnp.ndarray:
    """(n_blocks, n_ops) latency table F — Eq. 2's per-op terms."""
    row = np.array([op_latency(op, cfg, batch, seq, hw, decode=decode)
                    for op in ops], np.float32)
    return jnp.asarray(np.tile(row, (cfg.num_layers, 1)))


def expected_latency(alpha: jax.Array, lut: jax.Array) -> jax.Array:
    """Eq. 2: E[LAT] = sum_i <softmax(alpha_i), F_i>. Differentiable."""
    p = jax.nn.softmax(alpha, axis=-1)
    return jnp.sum(p * lut)


def sampled_latency(gates: jax.Array, lut: jax.Array) -> jax.Array:
    """Latency of one sampled (one-hot) architecture."""
    return jnp.sum(gates * lut)
