"""Differentiable hardware-aware architecture search (paper §2).

The search loop alternates:
  * weight step  — sample a path per block (Eq. 1), SGD on the active path's
    weights against training data;
  * arch step    — sample a path on *validation* data, backprop the combined
    loss (Eq. 3) into the architecture parameters alpha; the latency term
    uses the differentiable expected latency (Eq. 2) from the LUT.

Eq. 3 as printed (L = L_CE x alpha log(E[LAT]/ref)^beta) vanishes at
LAT == ref; we implement the MnasNet-style multiplicative form the text
describes ("combine the latency and training loss") plus ProxylessNAS's
additive form — select with `latency_loss`:
  mul:  L = CE * (E[LAT]/ref)^beta
  add:  L = CE + lam * E[LAT]/ref
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.supernet_lm import BACKBONE, CANDIDATE_OPS
from repro.core import latency_table as lt
from repro.core import supernet as sn
from repro.core.hardware_model import Hardware, V5E_POD

F32 = jnp.float32


@dataclasses.dataclass
class NASConfig:
    steps: int = 200
    warmup_steps: int = 100       # weight-only phase (uniform path sampling):
                                  # untrained paths lose to ZeroOp otherwise
    weight_lr: float = 5e-2
    alpha_lr: float = 3e-2
    lat_ref: float = 0.0          # 0 -> set to 0.6x uniform-mixture latency
    beta: float = 0.6             # latency exponent (mul) / weight (add)
    latency_loss: str = "mul"     # mul | add
    batch: int = 8
    seq: int = 128
    seed: int = 0
    log_every: int = 25


def combined_loss(ce, e_lat, ref, ncfg: NASConfig):
    """Latency pressure only ABOVE the target: the raw multiplicative form
    rewards shrinking below LAT_ref (loss -> 0 as arch -> all-ZeroOp), which
    collapses the search; clamping at the target keeps Eq. 3's trade-off
    semantics ('meet the budget, then maximize quality')."""
    rel = jnp.maximum(e_lat / ref, 1.0)
    if ncfg.latency_loss == "mul":
        return ce * jnp.power(rel, ncfg.beta)
    return ce + ncfg.beta * (rel - 1.0)


def search(data_iter: Callable[[int], Dict[str, jax.Array]],
           hw: Hardware = V5E_POD, ncfg: NASConfig = NASConfig(),
           cfg=BACKBONE, lut: Optional[jnp.ndarray] = None,
           progress: Optional[Callable[[dict], None]] = None) -> dict:
    """Run the search. data_iter(step) -> {tokens, labels}. Returns dict with
    alpha trajectory, derived arch, latency/ce curves."""
    key = jax.random.PRNGKey(ncfg.seed)
    params, alpha = sn.init_supernet(key, cfg)
    if lut is None:
        lut = lt.build_lut(cfg, ncfg.batch, ncfg.seq, hw)
    # default target: 60% of the uniform-mixture latency (a real budget --
    # ProxylessNAS's LAT_ref is the measured target-device budget)
    ref = ncfg.lat_ref or 0.6 * float(lt.expected_latency(alpha, lut))

    @jax.jit
    def weight_step(params, alpha, batch, key):
        gates = sn.sample_gates(key, alpha)
        loss, grads = jax.value_and_grad(sn.supernet_loss)(
            params, alpha, gates, batch, cfg)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / (gn + 1e-9))  # clip at norm 1
        params = jax.tree.map(
            lambda p, g: p - (ncfg.weight_lr * scale * g).astype(p.dtype),
            params, grads)
        return params, loss

    @jax.jit
    def alpha_step(params, alpha, batch, key):
        gates = sn.sample_gates(key, alpha)

        def loss_fn(a):
            ce = sn.supernet_loss(params, a, gates, batch, cfg)
            e_lat = lt.expected_latency(a, lut)
            return combined_loss(ce, e_lat, ref, ncfg), (ce, e_lat)

        (loss, (ce, e_lat)), ga = jax.value_and_grad(
            loss_fn, has_aux=True)(alpha)
        alpha = alpha - ncfg.alpha_lr * ga
        return alpha, loss, ce, e_lat

    hist: List[dict] = []
    uniform_alpha = jnp.zeros_like(alpha)
    for w in range(ncfg.warmup_steps):
        key, k1 = jax.random.split(key)
        params, _ = weight_step(params, uniform_alpha,
                                data_iter(2 * ncfg.steps + w), k1)

    for step in range(ncfg.steps):
        key, k1, k2 = jax.random.split(key, 3)
        params, wl = weight_step(params, alpha, data_iter(2 * step), k1)
        alpha, al, ce, e_lat = alpha_step(params, alpha,
                                          data_iter(2 * step + 1), k2)
        if step % ncfg.log_every == 0 or step == ncfg.steps - 1:
            rec = {"step": step, "weight_loss": float(wl),
                   "arch_loss": float(al), "val_ce": float(ce),
                   "e_lat_us": float(e_lat) * 1e6,
                   "arch": sn.derive_arch(alpha)}
            hist.append(rec)
            if progress:
                progress(rec)
    arch = sn.derive_arch(alpha)
    return {
        "alpha": np.asarray(alpha),
        "arch": arch,
        "e_lat_us": float(lt.expected_latency(alpha, lut)) * 1e6,
        "sampled_lat_us": float(lt.sampled_latency(
            jax.nn.one_hot(jnp.argmax(alpha, -1), len(CANDIDATE_OPS)),
            lut)) * 1e6,
        "history": hist,
        "params": params,
        "lat_ref_us": ref * 1e6,
    }


def synthetic_lm_data(cfg=BACKBONE, batch: int = 8, seq: int = 128,
                      seed: int = 0):
    """Deterministic synthetic next-token task with learnable structure
    (Zipf unigram + copy pattern) so search signal is non-trivial."""
    def it(step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng(seed + step)
        zipf = np.clip(rng.zipf(1.5, size=(batch, seq + 1)), 0,
                       cfg.vocab_size - 1)
        # inject copy structure: second half repeats first half
        half = (seq + 1) // 2
        zipf[:, half:2 * half] = zipf[:, :half]
        toks = jnp.asarray(zipf[:, :seq], jnp.int32)
        # chunked_ce shifts internally: labels are the same token stream
        return {"tokens": toks, "labels": toks}
    return it
