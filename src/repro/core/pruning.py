"""Structured pruning transforms for LM layers (AMC's compression backend).

Units are MXU-friendly structures: attention query-head GROUPS (GQA groups
prune together so grouped attention stays well-formed), FFN hidden units, and
MoE experts. Two modes:
  * mask_*  — zero out pruned units (fast policy evaluation in the RL env;
              shapes unchanged, so one jit serves every policy);
  * slice_* — physically shrink the tensors (the final exported model).

Importance criteria (magnitude-based, as AMC): L2 norm of the unit's
outgoing weights.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


# ----------------------------------------------------------- importance ----
# All functions accept optionally LAYER-STACKED params (leading scan dim):
# a stacked slot is one prunable layer in AMC, so importances reduce over
# every axis except the unit axis and the mask is shared across the stack.
def _sum_except(a: jax.Array, unit_axis: int) -> jax.Array:
    unit_axis %= a.ndim
    axes = tuple(i for i in range(a.ndim) if i != unit_axis)
    return jnp.sum(a.astype(F32) ** 2, axis=axes)


def head_group_importance(attn_p) -> jax.Array:
    """(n_kv,) importance of each GQA group = L2 of its wo rows + wq cols."""
    wo = attn_p["wo"]                          # (..., H, hd, D)
    wq = attn_p["wq"]                          # (..., D, H, hd)
    H = wo.shape[-3]
    K = attn_p["wk"].shape[-2]
    G = H // K
    per_head = jnp.sqrt(_sum_except(wo, -3) + _sum_except(wq, -2))
    return per_head.reshape(K, G).sum(axis=1)


def ffn_importance(ffn_p) -> jax.Array:
    """(d_ff,) importance of each hidden unit."""
    imp = _sum_except(ffn_p["w_out"], -2) + _sum_except(ffn_p["w_in"], -1)
    if "w_gate" in ffn_p:
        imp = imp + _sum_except(ffn_p["w_gate"], -1)
    return jnp.sqrt(imp)


def expert_importance(moe_p) -> jax.Array:
    """(E,) router-norm + weight-norm importance of each expert."""
    return jnp.sqrt(_sum_except(moe_p["router"], -1)
                    + _sum_except(moe_p["w_out"], -3))


def keep_mask(importance: jax.Array, keep_ratio) -> jax.Array:
    """Binary mask keeping the top keep_ratio fraction (at least 1 unit).
    Differentiable-free; keep_ratio may be traced (uses rank threshold)."""
    n = importance.shape[0]
    k = jnp.clip(jnp.round(keep_ratio * n), 1, n).astype(jnp.int32)
    order = jnp.argsort(-importance)
    ranks = jnp.argsort(order)
    return (ranks < k).astype(F32)


# ---------------------------------------------------------------- mask ----
# masks broadcast against TRAILING axes, so layer-stacked leading dims pass
# through untouched.
def mask_attn(attn_p, group_mask: jax.Array):
    """Zero out pruned GQA groups. group_mask (n_kv,)."""
    K = group_mask.shape[0]
    H = attn_p["wo"].shape[-3]
    G = H // K
    head_mask = jnp.repeat(group_mask, G)
    out = dict(attn_p)
    out["wq"] = attn_p["wq"] * head_mask[:, None].astype(attn_p["wq"].dtype)
    out["wo"] = attn_p["wo"] * head_mask[:, None, None] \
        .astype(attn_p["wo"].dtype)
    out["wk"] = attn_p["wk"] * group_mask[:, None].astype(attn_p["wk"].dtype)
    out["wv"] = attn_p["wv"] * group_mask[:, None].astype(attn_p["wv"].dtype)
    return out


def mask_ffn(ffn_p, unit_mask: jax.Array):
    out = dict(ffn_p)
    m = unit_mask.astype(ffn_p["w_in"].dtype)
    out["w_in"] = ffn_p["w_in"] * m
    if "w_gate" in ffn_p:
        out["w_gate"] = ffn_p["w_gate"] * m
    out["w_out"] = ffn_p["w_out"] * m[:, None]
    return out


def mask_experts(moe_p, expert_mask: jax.Array):
    out = dict(moe_p)
    out["router"] = moe_p["router"] + jnp.where(
        expert_mask > 0, 0.0, -1e9).astype(moe_p["router"].dtype)
    m = expert_mask.astype(moe_p["w_out"].dtype)
    out["w_out"] = moe_p["w_out"] * m[:, None, None]
    return out


# --------------------------------------------------------------- slice ----
def slice_ffn(ffn_p, keep_idx: np.ndarray):
    out = {"w_in": ffn_p["w_in"][:, keep_idx],
           "w_out": ffn_p["w_out"][keep_idx, :]}
    if "w_gate" in ffn_p:
        out["w_gate"] = ffn_p["w_gate"][:, keep_idx]
    return out


def slice_attn(attn_p, keep_groups: np.ndarray):
    K = attn_p["wk"].shape[1]
    H = attn_p["wq"].shape[1]
    G = H // K
    head_idx = np.concatenate([np.arange(g * G, (g + 1) * G)
                               for g in keep_groups])
    return {
        "wq": attn_p["wq"][:, head_idx],
        "wk": attn_p["wk"][:, keep_groups],
        "wv": attn_p["wv"][:, keep_groups],
        "wo": attn_p["wo"][head_idx],
    }


# ------------------------------------------------------------ flops ----
def block_flops(cfg, tokens: int) -> Dict[str, float]:
    """Per-block FLOPs split by prunable site (for AMC states/budget)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    gated = cfg.activation in ("swiglu", "geglu")
    attn = 2.0 * tokens * d * (H + 2 * K) * hd + 2.0 * tokens * H * hd * d
    ffn = 2.0 * tokens * d * cfg.d_ff * (3 if gated else 2)
    return {"attn": attn, "ffn": ffn}
