"""DDPG actor-critic in pure JAX — the agent behind AMC (§3) and HAQ (§4).

Continuous action in [0, 1] per step (sparsity ratio / normalized bitwidth),
truncated-normal exploration noise with decay, soft target updates, and a
numpy ring-buffer replay. Small MLPs (the paper's agents are 2x300 hidden) so
a full search runs in seconds on CPU.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@dataclasses.dataclass
class DDPGConfig:
    state_dim: int
    hidden: int = 128
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 1.0  # episodic, finite-horizon (AMC uses 1)
    tau: float = 0.01
    noise0: float = 0.5
    noise_decay: float = 0.99
    batch: int = 64
    buffer: int = 4096
    warmup_episodes: int = 8


def _mlp_init(key, sizes):
    params = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b), F32) / np.sqrt(a)
        params.append({"w": w, "b": jnp.zeros((b,), F32)})
    return params


def _mlp(params, x, final_act=None):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act else x


def actor_fwd(params, s):
    return _mlp(params, s, jax.nn.sigmoid)[..., 0]  # action in (0,1)


def critic_fwd(params, s, a):
    x = jnp.concatenate([s, a[..., None]], axis=-1)
    return _mlp(params, x)[..., 0]


class ReplayBuffer:
    def __init__(self, cap: int, state_dim: int):
        self.cap = cap
        self.s = np.zeros((cap, state_dim), np.float32)
        self.a = np.zeros((cap,), np.float32)
        self.r = np.zeros((cap,), np.float32)
        self.s2 = np.zeros((cap, state_dim), np.float32)
        self.done = np.zeros((cap,), np.float32)
        self.n = 0
        self.ptr = 0

    def add(self, s, a, r, s2, done):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i] = s2, done
        self.ptr = (i + 1) % self.cap
        self.n = min(self.n + 1, self.cap)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, size=batch)
        return (
            self.s[idx],
            self.a[idx],
            self.r[idx],
            self.s2[idx],
            self.done[idx],
        )


class DDPG:
    def __init__(self, cfg: DDPGConfig, seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        ka, kc = jax.random.split(key)
        self.actor = _mlp_init(ka, [cfg.state_dim, cfg.hidden, cfg.hidden, 1])
        self.critic = _mlp_init(
            kc, [cfg.state_dim + 1, cfg.hidden, cfg.hidden, 1]
        )
        self.t_actor = jax.tree.map(lambda x: x, self.actor)
        self.t_critic = jax.tree.map(lambda x: x, self.critic)
        self.buffer = ReplayBuffer(cfg.buffer, cfg.state_dim)
        self.rng = np.random.default_rng(seed)
        self.noise = cfg.noise0
        self.episode = 0
        self._train_step = jax.jit(self._make_train_step())

    # ---------------------------------------------------------------- api --
    def act(self, state: np.ndarray, explore: bool = True) -> float:
        a = float(actor_fwd(self.actor, jnp.asarray(state, F32)))
        if explore:
            # truncated-normal exploration (AMC's choice)
            a = float(np.clip(self.rng.normal(a, self.noise), 0.0, 1.0))
        return a

    def observe(self, s, a, r, s2, done):
        self.buffer.add(s, a, r, s2, float(done))

    def end_episode(self, updates: int = 32):
        self.episode += 1
        self.noise *= self.cfg.noise_decay
        if (
            self.episode < self.cfg.warmup_episodes
            or self.buffer.n < self.cfg.batch
        ):
            return {}
        losses = {}
        for _ in range(updates):
            batch = self.buffer.sample(self.rng, self.cfg.batch)
            out = self._train_step(
                self.actor,
                self.critic,
                self.t_actor,
                self.t_critic,
                *[jnp.asarray(b) for b in batch],
            )
            self.actor, self.critic, self.t_actor, self.t_critic, losses = out
        return {k: float(v) for k, v in losses.items()}

    # ------------------------------------------------------------- update --
    def _make_train_step(self):
        cfg = self.cfg

        def step(actor, critic, t_actor, t_critic, s, a, r, s2, done):
            q_next = critic_fwd(t_critic, s2, actor_fwd(t_actor, s2))
            target = r + cfg.gamma * (1.0 - done) * q_next

            def critic_loss(cp):
                q = critic_fwd(cp, s, a)
                err = q - jax.lax.stop_gradient(target)
                return jnp.mean(jnp.square(err))

            def actor_loss(ap):
                return -jnp.mean(critic_fwd(critic, s, actor_fwd(ap, s)))

            cl, gc = jax.value_and_grad(critic_loss)(critic)
            al, ga = jax.value_and_grad(actor_loss)(actor)
            critic = jax.tree.map(
                lambda p, g: p - cfg.critic_lr * g, critic, gc
            )
            actor = jax.tree.map(lambda p, g: p - cfg.actor_lr * g, actor, ga)
            t_critic = jax.tree.map(
                lambda t, p: (1 - cfg.tau) * t + cfg.tau * p, t_critic, critic
            )
            t_actor = jax.tree.map(
                lambda t, p: (1 - cfg.tau) * t + cfg.tau * p, t_actor, actor
            )
            return (
                actor,
                critic,
                t_actor,
                t_critic,
                {"critic_loss": cl, "actor_loss": al},
            )

        return step
