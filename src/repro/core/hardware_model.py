"""Analytic TPU hardware simulator — the paper's "hardware in the loop".

HAQ (§4) queries a hardware simulator for latency/energy feedback instead of
proxies (FLOPs); ProxylessNAS (§2) builds a per-op latency lookup table. The
container has no TPU, so this module plays the simulator role for both: a
roofline-based per-op cost model for TPU v5e-class chips, calibrated against
``compiled.cost_analysis()`` from the dry-run (see EXPERIMENTS.md §Roofline).

Three hardware targets mirror the paper's HW1/HW2/HW3 specialization story
(Table 5): a single edge chip (memory-bound decode), a pod slice
(compute-bound prefill/train), and a multi-pod slice (collective-bound).

All latencies are returned in seconds, energies in joules. Functions are
jnp-friendly: bits may be traced arrays, so HAQ's RL loop and the NAS latency
loss are differentiable end-to-end where they need to be.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    chips: int
    peak_flops_bf16: float = 197e12   # per chip
    peak_flops_int8: float = 394e12   # v5e int8 MXU path
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16 * 2**30
    vmem_bytes: float = 128 * 2**20
    # energy constants (public-literature scale values)
    pj_per_flop: float = 0.25         # bf16 MAC ~0.2-0.3 pJ on 5nm-class
    pj_per_hbm_byte: float = 120.0
    pj_per_ici_byte: float = 40.0
    mxu_dim: int = 128                # systolic array tile

    def peak_flops(self, w_bits) -> jax.Array:
        """Matmul peak vs weight precision: int8 path doubles throughput;
        sub-8-bit weights on TPU still use the int8 MXU (no extra compute
        speedup, only memory savings) — unlike BitFusion's bit-serial PEs.
        This asymmetry is exactly why TPU quantization policies differ from
        the paper's FPGA policies (DESIGN.md §2)."""
        w_bits = jnp.asarray(w_bits, jnp.float32)
        return jnp.where(w_bits <= 8, self.peak_flops_int8,
                         self.peak_flops_bf16)


V5E_EDGE = Hardware("v5e-1chip", chips=1)
V5E_POD = Hardware("v5e-pod256", chips=256)
V5E_2POD = Hardware("v5e-2pod512", chips=512,
                    ici_bw=25e9)  # pod axis traverses slower links

HARDWARES: Dict[str, Hardware] = {h.name: h for h in
                                  (V5E_EDGE, V5E_POD, V5E_2POD)}


def mxu_pad(dim, tile: int = 128):
    """Effective dim after MXU tile padding — why the NAS searcher learns to
    pick 128-aligned widths (the paper's 7x7-conv-on-GPU moment, on TPU)."""
    dim = jnp.asarray(dim, jnp.float32)
    return jnp.ceil(dim / tile) * tile


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Roofline terms for one op at one precision setting."""
    flops: jax.Array
    weight_bytes: jax.Array
    act_bytes: jax.Array
    coll_bytes: jax.Array = 0.0

    def latency(self, hw: Hardware, w_bits=16, a_bits=16) -> jax.Array:
        w_bits = jnp.asarray(w_bits, jnp.float32)
        a_bits = jnp.asarray(a_bits, jnp.float32)
        t_comp = self.flops / (hw.peak_flops(w_bits) * hw.chips)
        bytes_total = (self.weight_bytes * w_bits / 16.0
                       + self.act_bytes * a_bits / 16.0)
        t_mem = bytes_total / (hw.hbm_bw * hw.chips)
        t_coll = self.coll_bytes / (hw.ici_bw * hw.chips)
        return jnp.maximum(jnp.maximum(t_comp, t_mem), t_coll)

    def energy(self, hw: Hardware, w_bits=16, a_bits=16) -> jax.Array:
        w_bits = jnp.asarray(w_bits, jnp.float32)
        a_bits = jnp.asarray(a_bits, jnp.float32)
        # MAC energy scales ~linearly with operand width on MXU-class units
        e_flop = self.flops * hw.pj_per_flop * 1e-12 * \
            jnp.minimum(w_bits, a_bits) / 16.0
        e_mem = (self.weight_bytes * w_bits / 16.0
                 + self.act_bytes * a_bits / 16.0) * hw.pj_per_hbm_byte * 1e-12
        e_coll = self.coll_bytes * hw.pj_per_ici_byte * 1e-12
        return e_flop + e_mem + e_coll

    def intensity(self, w_bits=16, a_bits=16) -> jax.Array:
        """Operational intensity (FLOPs per HBM byte) — Fig. 4's x-axis."""
        b = (self.weight_bytes * jnp.asarray(w_bits, jnp.float32) / 16.0
             + self.act_bytes * jnp.asarray(a_bits, jnp.float32) / 16.0)
        return self.flops / jnp.maximum(b, 1.0)


# ------------------------------------------------------------- op costs ----
def linear_cost(tokens: int, d_in: int, d_out: int, *, tp: int = 1,
                pad: bool = True) -> OpCost:
    """Dense matmul (tokens, d_in) x (d_in, d_out), TP-sharded on d_out."""
    di = mxu_pad(d_in) if pad else jnp.asarray(float(d_in))
    do = mxu_pad(d_out) if pad else jnp.asarray(float(d_out))
    flops = 2.0 * tokens * di * do
    return OpCost(
        flops=flops,
        weight_bytes=di * do * 2.0,
        act_bytes=2.0 * tokens * (di + do),
        coll_bytes=2.0 * tokens * do / max(tp, 1),  # partial-sum reduce
    )


def attention_cost(batch: int, q_len: int, kv_len: int, n_heads: int,
                   n_kv: int, head_dim: int, *, window: int = 0,
                   decode: bool = False, kv_bits: int = 16) -> OpCost:
    """``kv_bits`` scales the KV-cache read traffic (the decode memory-
    roofline term) for a HAQ-quantized page pool: int8 halves it, int4
    quarters it, plus the fp32 per-token per-head scale tiles the pool
    stores alongside the codes (serving/kvquant). Compute is unchanged —
    dequant rides the block walk on the VPU."""
    eff_kv = min(window, kv_len) if window else kv_len
    flops = 4.0 * batch * q_len * eff_kv * n_heads * head_dim
    kv_bytes = 2.0 * batch * eff_kv * n_kv * head_dim * 2.0 * (kv_bits / 16.0)
    if kv_bits < 16:
        kv_bytes += 2.0 * batch * eff_kv * n_kv * 4.0   # scale tiles
    act = 2.0 * batch * q_len * n_heads * head_dim * 2.0
    return OpCost(flops=jnp.asarray(flops),
                  weight_bytes=jnp.asarray(0.0),
                  act_bytes=jnp.asarray(kv_bytes + act))


def allreduce_cost(tokens: int, d_model: int, shards: int) -> OpCost:
    """Ring all-reduce of a (tokens, d_model) bf16 activation across a
    tensor-parallel group: every rank moves ~2*(N-1)/N of the buffer over
    ICI. This is the per-layer activation-collective term of
    ``step_latency(mesh_model=N)`` for the sharded serving engine (its
    gather-based exact TP moves the same activation volume as the
    canonical Megatron pair) — the price of splitting the per-shard HBM
    roofline N ways (paper Fig. 4's bandwidth axis traded against the
    interconnect)."""
    n = max(int(shards), 1)
    coll = 2.0 * tokens * d_model * 2.0 * (n - 1) / n
    return OpCost(flops=jnp.asarray(0.0),
                  weight_bytes=jnp.asarray(0.0),
                  act_bytes=jnp.asarray(0.0),
                  coll_bytes=jnp.asarray(coll))


def gather_cost(nbytes, shards: int) -> OpCost:
    """Ring all-gather of ``nbytes`` of sharded-at-rest state onto every
    rank ((N-1)/N of the buffer crosses ICI per rank): how the SPMD
    serving engine pays for its FSDP-style gather-at-use weights (attn
    out-projection, FFN down-projection, MoE expert bank, embed table) —
    the contraction-sharded matmuls it deliberately refuses to psum-split
    for bit-exactness (serving/engine/sharded.py)."""
    n = max(int(shards), 1)
    return OpCost(flops=jnp.asarray(0.0),
                  weight_bytes=jnp.asarray(0.0),
                  act_bytes=jnp.asarray(0.0),
                  coll_bytes=jnp.asarray(float(nbytes) * (n - 1) / n))


def ssd_cost(batch: int, seq: int, d_inner: int, d_state: int,
             chunk: int) -> OpCost:
    """Mamba2 SSD: intra-chunk quadratic + state updates."""
    heads = max(d_inner // 64, 1)
    intra = 2.0 * batch * seq * chunk * heads * 64
    state = 4.0 * batch * seq * d_inner * d_state
    return OpCost(flops=jnp.asarray(intra + state),
                  weight_bytes=jnp.asarray(0.0),
                  act_bytes=jnp.asarray(2.0 * batch * seq * d_inner * 2.0))


def moe_cost(tokens: int, d_model: int, d_ff: int, n_experts: int,
             top_k: int, *, ep: int = 1) -> OpCost:
    """Top-k expert FFN + all-to-all dispatch."""
    active = linear_cost(tokens * top_k, d_model, d_ff)
    a2a = 2.0 * tokens * top_k * d_model * 2.0  # dispatch + combine
    return OpCost(
        flops=active.flops * 3.0,                       # in/gate/out
        weight_bytes=mxu_pad(d_model) * mxu_pad(d_ff) * 3.0 * n_experts * 2.0,
        act_bytes=active.act_bytes * 3.0,
        coll_bytes=jnp.asarray(a2a),
    )
