"""AMC — AutoML for Model Compression (paper §3), LM-adapted.

A DDPG agent walks the prunable layers of a trained model. Per layer it
observes an 11-dim embedding (AMC's state: layer index, type, dims, FLOPs
fractions, reduced-so-far, rest, previous action) and emits a KEEP ratio
a_t in [a_min, 1]. Budget enforcement follows AMC's resource-constrained
protocol: before each action, the env computes the minimum keep ratio that
still allows the REMAINING layers (at max prune) to hit the FLOPs target,
and clips the action into the feasible interval.

Reward (AMC's FLOPs-constrained form): R = -ΔCE measured on a held-out
batch with the masked model — the budget is met by construction, so reward
is pure quality. A latency-constrained variant queries the TPU hardware
model instead of FLOPs (paper Table 3's "0.5x latency" row).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.core.rl.ddpg import DDPG, DDPGConfig
from repro.core.hardware_model import Hardware, V5E_POD

F32 = jnp.float32
STATE_DIM = 11


@dataclasses.dataclass
class AMCConfig:
    target: float = 0.5           # FLOPs (or latency) budget, fraction
    a_min: float = 0.2            # min keep ratio per layer
    episodes: int = 60
    mode: str = "flops"           # flops | latency
    seed: int = 0


class PrunableLayer:
    def __init__(self, name: str, kind: str, path: Tuple, n_units: int,
                 flops: float):
        self.name = name
        self.kind = kind          # attn | ffn | moe
        self.path = path          # keys into the params pytree
        self.n_units = n_units
        self.flops = flops


def enumerate_layers(model, tokens: int) -> List[PrunableLayer]:
    """Prunable layers of a (dense/moe family) model: per scanned sub-layer
    slot, attention groups + FFN units (stacked layers prune jointly — the
    structured analogue of AMC treating a conv layer as one unit)."""
    cfg = model.cfg
    from repro.models.transformer import period_of, sublayer_kinds
    layers: List[PrunableLayer] = []
    if cfg.family in ("ssm",):
        return layers  # d_inner pruning handled as ffn-like below if needed
    P = period_of(cfg)
    kinds = sublayer_kinds(cfg)
    fl = pruning.block_flops(cfg, tokens)
    n_groups = cfg.num_layers // P
    for j in range(P):
        layers.append(PrunableLayer(
            f"sub{j}/attn", "attn", ("blocks", f"sub{j}", "attn"),
            cfg.num_kv_heads, fl["attn"] * n_groups))
        if kinds[j]["moe"]:
            layers.append(PrunableLayer(
                f"sub{j}/moe", "moe", ("blocks", f"sub{j}", "moe"),
                cfg.moe.num_experts,
                fl["ffn"] * n_groups))
        else:
            layers.append(PrunableLayer(
                f"sub{j}/ffn", "ffn", ("blocks", f"sub{j}", "ffn"),
                cfg.d_ff, fl["ffn"] * n_groups))
    return layers


def _get(params, path):
    node = params
    for k in path:
        node = node[k]
    return node


def _set(params, path, value):
    if not path:
        return value
    out = dict(params)
    out[path[0]] = _set(params[path[0]], path[1:], value)
    return out


def apply_ratios(params, layers: List[PrunableLayer],
                 ratios: List[float]) -> Dict:
    """Mask-prune every layer at its keep ratio (jit-friendly shapes)."""
    out = params
    for layer, r in zip(layers, ratios):
        p = _get(out, layer.path)
        if layer.kind == "attn":
            imp = pruning.head_group_importance(p)
            masked = pruning.mask_attn(p, pruning.keep_mask(imp, r))
        elif layer.kind == "moe":
            imp = pruning.expert_importance(p)
            masked = pruning.mask_experts(p, pruning.keep_mask(imp, r))
        else:
            imp = pruning.ffn_importance(p)
            masked = pruning.mask_ffn(p, pruning.keep_mask(imp, r))
        out = _set(out, layer.path, masked)
    return out


class AMCEnv:
    """Episode = one pass over prunable layers; terminal reward = -ΔCE."""

    def __init__(self, model, params, eval_loss: Callable[[Dict], float],
                 acfg: AMCConfig, tokens: int = 4096,
                 hw: Hardware = V5E_POD):
        self.model = model
        self.params = params
        self.eval_loss = eval_loss
        self.acfg = acfg
        self.layers = enumerate_layers(model, tokens)
        assert self.layers, f"no prunable layers for {model.cfg.name}"
        self.total_flops = sum(l.flops for l in self.layers)
        self.base_loss = float(eval_loss(params))
        self.hw = hw

    # -------------------------------------------------------------- state --
    def state(self, t: int, reduced: float, prev_a: float) -> np.ndarray:
        L = self.layers[t]
        rest = sum(l.flops for l in self.layers[t + 1:]) / self.total_flops
        return np.array([
            t / max(len(self.layers) - 1, 1),
            1.0 if L.kind == "attn" else 0.0,
            1.0 if L.kind == "ffn" else 0.0,
            1.0 if L.kind == "moe" else 0.0,
            L.n_units / 1024.0,
            np.log10(max(L.flops, 1.0)) / 15.0,
            L.flops / self.total_flops,
            reduced,
            rest,
            prev_a,
            self.acfg.target,
        ], np.float32)

    # ----------------------------------------------------------- feasible --
    def feasible_interval(self, t: int, flops_used: float) -> Tuple[float, float]:
        """Keep-ratio bounds so the target stays achievable (AMC's budget
        enforcement: later layers can always be pruned to a_min)."""
        target_flops = self.acfg.target * self.total_flops
        rest_min = sum(l.flops for l in self.layers[t + 1:]) * self.acfg.a_min
        L = self.layers[t]
        a_max = (target_flops - flops_used - rest_min) / L.flops
        return self.acfg.a_min, float(np.clip(a_max, self.acfg.a_min, 1.0))

    # ------------------------------------------------------------ episode --
    def rollout(self, agent: DDPG, explore: bool = True) -> dict:
        ratios: List[float] = []
        transitions = []
        reduced, prev_a, flops_used = 0.0, 1.0, 0.0
        for t in range(len(self.layers)):
            s = self.state(t, reduced, prev_a)
            a = agent.act(s, explore=explore)
            lo, hi = self.feasible_interval(t, flops_used)
            a = float(np.clip(self.acfg.a_min + a * (1 - self.acfg.a_min),
                              lo, hi))
            ratios.append(a)
            flops_used += self.layers[t].flops * a
            reduced = flops_used / self.total_flops
            prev_a = a
            transitions.append((s, (a - self.acfg.a_min)
                                / (1 - self.acfg.a_min)))
        masked = apply_ratios(self.params, self.layers, ratios)
        loss = float(self.eval_loss(masked))
        reward = -(loss - self.base_loss)
        for t, (s, a) in enumerate(transitions):
            s2 = self.state(min(t + 1, len(self.layers) - 1),
                            reduced, ratios[t]) \
                if t + 1 < len(self.layers) else np.zeros(STATE_DIM, np.float32)
            agent.observe(s, a, reward if t == len(transitions) - 1 else 0.0,
                          s2, t == len(transitions) - 1)
        return {"ratios": ratios, "loss": loss, "reward": reward,
                "flops_frac": flops_used / self.total_flops}


def search(model, params, eval_loss, acfg: AMCConfig = AMCConfig(),
           progress: Optional[Callable[[dict], None]] = None) -> dict:
    env = AMCEnv(model, params, eval_loss, acfg)
    agent = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=acfg.seed)
    best = None
    hist = []
    for ep in range(acfg.episodes):
        rec = env.rollout(agent, explore=True)
        agent.end_episode()
        rec["episode"] = ep
        hist.append({k: rec[k] for k in ("episode", "loss", "reward",
                                         "flops_frac")})
        if best is None or rec["reward"] > best["reward"]:
            best = rec
        if progress and ep % 10 == 0:
            progress(rec)
    final = env.rollout(agent, explore=False)
    if final["reward"] > best["reward"]:
        best = final
    return {"best": best, "history": hist, "base_loss": env.base_loss,
            "layers": [l.name for l in env.layers]}


def uniform_baseline(model, params, eval_loss, keep: float) -> dict:
    """The paper's rule-based comparison: uniform width multiplier."""
    env_layers = enumerate_layers(model, 4096)
    masked = apply_ratios(params, env_layers, [keep] * len(env_layers))
    return {"loss": float(eval_loss(masked)), "keep": keep}
