"""Quantizers shared by HAQ, the PACT baseline, and the serving path.

Weights: symmetric per-output-channel int quantization (paper's linear
quantization; centroids/k-means from Deep Compression don't map to the MXU).
Activations: PACT-style clipped range [Choi et al. 2018], the paper's §4
comparison baseline.

``fake_quant_*`` return dequantized fp values (QAT / HAQ policy evaluation);
``quantize_weight`` returns the int tensor + scale consumed by
``repro.kernels.quant_matmul`` at serving time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def qmax(bits) -> jax.Array:
    return 2.0 ** (jnp.asarray(bits, F32) - 1.0) - 1.0


def quantize_weight(w: jax.Array, bits, *, axis: int = -1
                    ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-channel (along `axis`-complement) int quantization.
    Returns (q int8-ish stored values, scale) with w ~= q * scale."""
    wf = w.astype(F32)
    red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
    amax = jnp.max(jnp.abs(wf), axis=red, keepdims=True)
    scale = amax / jnp.maximum(qmax(bits), 1.0) + 1e-12
    q = jnp.clip(jnp.round(wf / scale), -qmax(bits), qmax(bits))
    return q, scale


def fake_quant_weight(w: jax.Array, bits, *, axis: int = -1) -> jax.Array:
    q, scale = quantize_weight(w, bits, axis=axis)
    return (q * scale).astype(w.dtype)


def fake_quant_act(x: jax.Array, bits, clip: float = 6.0) -> jax.Array:
    """PACT: clip to [-c, c] (signed) then uniform-quantize."""
    xf = x.astype(F32)
    c = jnp.asarray(clip, F32)
    xf = jnp.clip(xf, -c, c)
    scale = c / jnp.maximum(qmax(bits), 1.0)
    return (jnp.round(xf / scale) * scale).astype(x.dtype)


def quant_error(w: jax.Array, bits, *, axis: int = -1) -> jax.Array:
    """Relative L2 reconstruction error (HAQ state feature)."""
    wq = fake_quant_weight(w, bits, axis=axis)
    num = jnp.sum(jnp.square((w - wq).astype(F32)))
    den = jnp.sum(jnp.square(w.astype(F32))) + 1e-12
    return jnp.sqrt(num / den)


# ------------------------------------------------------- policy -> params ----
def apply_weight_policy(params, policy: Dict[str, int], site_of) -> dict:
    """Fake-quantize every weight leaf whose site (via site_of(path)) appears
    in `policy` (site -> bits). Non-matmul leaves (norms, biases) stay fp."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    out = []
    for path, leaf in flat:
        site = site_of(jax.tree_util.keystr(path), leaf)
        if site is not None and site in policy and leaf.ndim >= 2:
            out.append(fake_quant_weight(leaf, policy[site]))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def default_site_of(keystr: str, leaf) -> str | None:
    """Map a param path to a HAQ policy site (layer-kind granularity)."""
    for token, site in [
        ("'wq'", "attn_q"), ("'wk'", "attn_k"), ("'wv'", "attn_v"),
        ("'wo'", "attn_o"), ("'w_in'", "ffn_in"), ("'w_gate'", "ffn_gate"),
        ("'w_out'", "ffn_out"), ("'in_proj'", "ssm_in"),
        ("'out_proj'", "ssm_out"), ("'lm_head'", "lm_head"),
        ("'embed'", "embed"), ("'fuse_in'", "fuse"), ("'fuse_out'", "fuse"),
    ]:
        if token in keystr:
            return site
    return None


def make_quant_dot(policy: Dict[str, Tuple[int, int]], *, use_kernel=False):
    """Build the `dot` hook threaded through the models: per-site
    (w_bits, a_bits) fake-quant (or the Pallas int8 kernel when use_kernel
    and bits allow). Sites not in the policy run in bf16."""

    def dot(x, w, name):
        eq = _einsum_for(x, w)
        if name not in policy:
            return jnp.einsum(eq, x, w)
        w_bits, a_bits = policy[name]
        if w_bits >= 16 and a_bits >= 16:   # full precision: exact no-op
            return jnp.einsum(eq, x, w)
        if use_kernel and w.ndim == 2 and w_bits <= 8:
            from repro.kernels import ops as kops
            return kops.quant_matmul(x, w, w_bits=int(w_bits),
                                     a_bits=int(a_bits))
        wq = fake_quant_weight(w, w_bits)
        xq = fake_quant_act(x, a_bits) if a_bits and a_bits < 16 else x
        return jnp.einsum(eq, xq, wq)

    return dot


def _einsum_for(x, w):
    """Reconstruct the einsum the model sites use, from operand ranks."""
    if w.ndim == 2:
        return "...d,df->...f"
    if x.ndim == 4 and w.ndim == 3:
        return "bsnh,nhd->bsd"     # attention output projection
    if w.ndim == 3 and x.ndim == 3 and w.shape[0] == x.shape[0] \
            and x.shape[-1] == w.shape[1]:
        return "ecd,edf->ecf"      # moe expert batch
    if w.ndim == 3:
        return "bsd,dnh->bsnh"     # qkv projection
    raise ValueError((x.shape, w.shape))
