"""HAQ — Hardware-Aware Automated Quantization (paper §4), TPU-adapted.

DDPG agent assigns per-site (w_bits, a_bits); the TPU roofline simulator
(core/hardware_model.py) provides DIRECT latency/energy feedback — never
FLOPs proxies. Budget enforcement is the paper's exact mechanism: "if the
current policy exceeds our resource budget, we sequentially decrease the
bitwidth of each layer until the constraint is finally satisfied".

Weight bits ∈ {2..8}, activation bits ∈ {4..8,16}; on TPU the compute
speedup step-functions at 8 bits (int8 MXU) while HBM traffic scales
linearly with bits — which is why the learned TPU policies differ from the
paper's BitFusion/BISMO policies (DESIGN.md §2): decode (memory-bound)
drives weights to 2-4 bits, prefill (compute-bound) parks them at 8.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import quantization as q
from repro.core.hardware_model import Hardware, V5E_EDGE, OpCost, linear_cost
from repro.core.rl.ddpg import DDPG, DDPGConfig

STATE_DIM = 10
W_BITS = (2, 3, 4, 5, 6, 7, 8)
A_BITS = (4, 5, 6, 7, 8, 16)


@dataclasses.dataclass
class HAQConfig:
    latency_budget: float = 0.0     # seconds; 0 -> derived as frac of 8-bit
    budget_frac: float = 0.7        # budget = frac * latency(W8A8)
    episodes: int = 60
    quality_coef: float = 1.0       # reward = -coef * ΔCE
    seed: int = 0
    mode: str = "latency"           # latency | energy | size


class QuantSite:
    """One quantizable matmul site (layer-kind granularity, both stacks)."""

    def __init__(self, name: str, tokens: int, d_in: int, d_out: int,
                 count: int):
        self.name = name
        self.tokens = tokens
        self.d_in = d_in
        self.d_out = d_out
        self.count = count          # layers sharing this site
        self.cost: OpCost = linear_cost(tokens, d_in, d_out)

    def latency(self, hw, w_bits, a_bits) -> float:
        return float(self.cost.latency(hw, w_bits, a_bits)) * self.count

    def energy(self, hw, w_bits, a_bits) -> float:
        return float(self.cost.energy(hw, w_bits, a_bits)) * self.count

    def size_bytes(self, w_bits) -> float:
        return float(self.cost.weight_bytes) * w_bits / 16.0 * self.count


def enumerate_sites(cfg, batch: int, seq: int, *, decode=False
                    ) -> List[QuantSite]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    L = cfg.num_layers
    tokens = batch * (1 if decode else seq)
    sites = [
        QuantSite("attn_q", tokens, d, H * hd, L),
        QuantSite("attn_k", tokens, d, K * hd, L),
        QuantSite("attn_v", tokens, d, K * hd, L),
        QuantSite("attn_o", tokens, H * hd, d, L),
    ]
    gated = cfg.activation in ("swiglu", "geglu")
    if cfg.moe:
        n_moe = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
        ff = cfg.moe.d_ff_expert
        k = cfg.moe.experts_per_token
        sites += [
            QuantSite("moe_in", tokens * k, d, ff, n_moe),
            QuantSite("moe_gate", tokens * k, d, ff, n_moe),
            QuantSite("moe_out", tokens * k, ff, d, n_moe),
        ]
        n_dense = L - n_moe
    else:
        n_dense = L
    if cfg.d_ff and n_dense:
        sites += [QuantSite("ffn_in", tokens, d, cfg.d_ff, n_dense),
                  QuantSite("ffn_out", tokens, cfg.d_ff, d, n_dense)]
        if gated:
            sites.append(QuantSite("ffn_gate", tokens, d, cfg.d_ff, n_dense))
    if cfg.ssm:
        di = cfg.d_inner
        s = cfg.ssm
        proj = 2 * di + 2 * s.n_groups * s.d_state + cfg.ssm_heads
        sites += [QuantSite("ssm_in", tokens, d, proj, L),
                  QuantSite("ssm_out", tokens, di, d, L)]
    return sites


def resource(sites, wa: List[Tuple[int, int]], hw: Hardware,
             mode: str) -> float:
    if mode == "latency":
        return sum(s.latency(hw, w, a) for s, (w, a) in zip(sites, wa))
    if mode == "energy":
        return sum(s.energy(hw, w, a) for s, (w, a) in zip(sites, wa))
    return sum(s.size_bytes(w) for s, (w, _) in zip(sites, wa))


def enforce_budget(sites, wa: List[Tuple[int, int]], hw: Hardware,
                   budget: float, mode: str) -> List[Tuple[int, int]]:
    """Paper's back-off: sequentially decrement bitwidths until it fits."""
    wa = list(wa)
    guard = 0
    while resource(sites, wa, hw, mode) > budget and guard < 10_000:
        # decrement the site with the largest resource contribution that can
        # still go lower (sequential sweep, as in the paper)
        changed = False
        for i in range(len(wa)):
            w, a = wa[i]
            if a > min(A_BITS):
                wa[i] = (w, A_BITS[A_BITS.index(a) - 1])
                changed = True
            elif w > min(W_BITS):
                wa[i] = (w - 1, a)
                changed = True
            if changed and resource(sites, wa, hw, mode) <= budget:
                return wa
        if not changed:
            break
        guard += 1
    return wa


class HAQEnv:
    def __init__(self, cfg, sites: List[QuantSite],
                 eval_policy: Callable[[Dict[str, Tuple[int, int]]], float],
                 hcfg: HAQConfig, hw: Hardware = V5E_EDGE):
        self.cfg = cfg
        self.sites = sites
        self.eval_policy = eval_policy
        self.hcfg = hcfg
        self.hw = hw
        base = [(8, 8)] * len(sites)
        self.base_resource = resource(sites, base, hw, hcfg.mode)
        self.budget = hcfg.latency_budget or hcfg.budget_frac * \
            self.base_resource
        self.base_loss = float(eval_policy({s.name: (16, 16)
                                            for s in sites}))

    def state(self, t: int, prev_w: int, prev_a: int) -> np.ndarray:
        s = self.sites[t]
        return np.array([
            t / max(len(self.sites) - 1, 1),
            np.log10(max(float(s.cost.flops), 1.0)) / 15.0,
            np.log10(max(float(s.cost.weight_bytes), 1.0)) / 12.0,
            float(s.cost.intensity()) / 1000.0,
            s.d_in / 16384.0,
            s.d_out / 16384.0,
            s.count / 100.0,
            prev_w / 8.0,
            prev_a / 16.0,
            self.budget / max(self.base_resource, 1e-12),
        ], np.float32)

    def decode_action(self, a: float, arr) -> int:
        idx = int(round(a * (len(arr) - 1)))
        return arr[max(0, min(idx, len(arr) - 1))]

    def rollout(self, agent_w: DDPG, agent_a: DDPG, explore=True) -> dict:
        wa: List[Tuple[int, int]] = []
        traj = []
        pw, pa = 8, 8
        for t in range(len(self.sites)):
            s = self.state(t, pw, pa)
            aw = agent_w.act(s, explore=explore)
            aa = agent_a.act(s, explore=explore)
            w_bits = self.decode_action(aw, W_BITS)
            a_bits = self.decode_action(aa, A_BITS)
            wa.append((w_bits, a_bits))
            traj.append((s, aw, aa))
            pw, pa = w_bits, a_bits
        wa = enforce_budget(self.sites, wa, self.hw, self.budget,
                            self.hcfg.mode)
        policy = {s.name: b for s, b in zip(self.sites, wa)}
        loss = float(self.eval_policy(policy))
        reward = -self.hcfg.quality_coef * (loss - self.base_loss)
        for t, (s, aw, aa) in enumerate(traj):
            done = t == len(traj) - 1
            s2 = self.state(min(t + 1, len(self.sites) - 1), *wa[t]) \
                if not done else np.zeros(STATE_DIM, np.float32)
            r = reward if done else 0.0
            agent_w.observe(s, aw, r, s2, done)
            agent_a.observe(s, aa, r, s2, done)
        used = resource(self.sites, wa, self.hw, self.hcfg.mode)
        return {"policy": policy, "loss": loss, "reward": reward,
                "resource": used, "budget": self.budget,
                "base_resource": self.base_resource}


def search(cfg, sites, eval_policy, hcfg: HAQConfig = HAQConfig(),
           hw: Hardware = V5E_EDGE,
           agents: Optional[Tuple[DDPG, DDPG]] = None,
           progress: Optional[Callable[[dict], None]] = None) -> dict:
    """Returns best policy + history (+ the trained agents for Table 7's
    transfer experiment)."""
    env = HAQEnv(cfg, sites, eval_policy, hcfg, hw)
    if agents is None:
        agent_w = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=hcfg.seed)
        agent_a = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=hcfg.seed + 1)
    else:
        agent_w, agent_a = agents
    best, hist = None, []
    for ep in range(hcfg.episodes):
        rec = env.rollout(agent_w, agent_a, explore=True)
        agent_w.end_episode()
        agent_a.end_episode()
        hist.append({"episode": ep, "loss": rec["loss"],
                     "reward": rec["reward"], "resource": rec["resource"]})
        if best is None or rec["reward"] > best["reward"]:
            best = rec
        if progress and ep % 10 == 0:
            progress(rec)
    final = env.rollout(agent_w, agent_a, explore=False)
    if final["reward"] > best["reward"]:
        best = final
    return {"best": best, "history": hist, "base_loss": env.base_loss,
            "agents": (agent_w, agent_a),
            "sites": [s.name for s in env.sites]}
