"""HAQ — Hardware-Aware Automated Quantization (paper §4), TPU-adapted.

DDPG agent assigns per-site (w_bits, a_bits); the TPU roofline simulator
(core/hardware_model.py) provides DIRECT latency/energy feedback — never
FLOPs proxies. Budget enforcement is the paper's exact mechanism: "if the
current policy exceeds our resource budget, we sequentially decrease the
bitwidth of each layer until the constraint is finally satisfied".

Weight bits ∈ {2..8}, activation bits ∈ {4..8,16}; on TPU the compute
speedup step-functions at 8 bits (int8 MXU) while HBM traffic scales
linearly with bits — which is why the learned TPU policies differ from the
paper's BitFusion/BISMO policies (DESIGN.md §2): decode (memory-bound)
drives weights to 2-4 bits, prefill (compute-bound) parks them at 8.

Beyond weights, ``KVCacheSite``/``enumerate_kv_sites`` expose the serving
engine's paged KV-cache pool to the same machinery (KV bits ∈ {4, 8, 16}):
at long contexts KV bytes, not weight bytes, dominate the decode roofline.
The search loop for those sites lives in serving/kvquant/policy.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware_model import (Hardware, V5E_EDGE, OpCost,
                                       attention_cost, linear_cost)
from repro.core.rl.ddpg import DDPG, DDPGConfig

STATE_DIM = 10
W_BITS = (2, 3, 4, 5, 6, 7, 8)
A_BITS = (4, 5, 6, 7, 8, 16)
# Storable KV-cache bitwidths: the page pool is bf16, int8, or int4 packed
# along head_dim (serving/kvquant) — no other layouts exist at serve time.
KV_BITS = (4, 8, 16)


@dataclasses.dataclass
class HAQConfig:
    latency_budget: float = 0.0     # seconds; 0 -> derived as frac of 8-bit
    budget_frac: float = 0.7        # budget = frac * latency(W8A8)
    episodes: int = 60
    quality_coef: float = 1.0       # reward = -coef * ΔCE
    seed: int = 0
    mode: str = "latency"           # latency | energy | size


class QuantSite:
    """One quantizable matmul site (layer-kind granularity, both stacks)."""

    def __init__(self, name: str, tokens: int, d_in: int, d_out: int,
                 count: int):
        self.name = name
        self.tokens = tokens
        self.d_in = d_in
        self.d_out = d_out
        self.count = count          # layers sharing this site
        self.cost: OpCost = linear_cost(tokens, d_in, d_out)

    def latency(self, hw, w_bits, a_bits) -> float:
        return float(self.cost.latency(hw, w_bits, a_bits)) * self.count

    def energy(self, hw, w_bits, a_bits) -> float:
        return float(self.cost.energy(hw, w_bits, a_bits)) * self.count

    def size_bytes(self, w_bits) -> float:
        return float(self.cost.weight_bytes) * w_bits / 16.0 * self.count


def enumerate_sites(cfg, batch: int, seq: int, *, decode=False
                    ) -> List[QuantSite]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    L = cfg.num_layers
    tokens = batch * (1 if decode else seq)
    sites = [
        QuantSite("attn_q", tokens, d, H * hd, L),
        QuantSite("attn_k", tokens, d, K * hd, L),
        QuantSite("attn_v", tokens, d, K * hd, L),
        QuantSite("attn_o", tokens, H * hd, d, L),
    ]
    gated = cfg.activation in ("swiglu", "geglu")
    if cfg.moe:
        n_moe = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
        ff = cfg.moe.d_ff_expert
        k = cfg.moe.experts_per_token
        sites += [
            QuantSite("moe_in", tokens * k, d, ff, n_moe),
            QuantSite("moe_gate", tokens * k, d, ff, n_moe),
            QuantSite("moe_out", tokens * k, ff, d, n_moe),
        ]
        n_dense = L - n_moe
    else:
        n_dense = L
    if cfg.d_ff and n_dense:
        sites += [QuantSite("ffn_in", tokens, d, cfg.d_ff, n_dense),
                  QuantSite("ffn_out", tokens, cfg.d_ff, d, n_dense)]
        if gated:
            sites.append(QuantSite("ffn_gate", tokens, d, cfg.d_ff, n_dense))
    if cfg.ssm:
        di = cfg.d_inner
        s = cfg.ssm
        proj = 2 * di + 2 * s.n_groups * s.d_state + cfg.ssm_heads
        sites += [QuantSite("ssm_in", tokens, d, proj, L),
                  QuantSite("ssm_out", tokens, di, d, L)]
    return sites


class KVCacheSite:
    """One KV-cache quantization site: the k/v pages of one sub-layer slot
    (all ``count`` layers sharing it) in the serving engine's paged pool.

    Duck-types QuantSite so the HAQ machinery (state features, resource
    accounting, budget back-off) applies unchanged — here "w_bits" are the
    *stored KV bits* (KV_BITS: 4/8/16) and a_bits are ignored: the query is
    always fp and dequant rides the attention block walk. Latency/energy
    feedback comes from the same roofline (hardware_model.attention_cost
    with ``kv_bits``) that admission.step_latency queries at serve time;
    size is the resident KV footprint at a given batch/context.

    ``local`` records the attention kind: sliding-window layers see a
    bounded effective context, which is the sensitivity proxy
    serving/kvquant/policy.py uses to gate which sites may drop to int4.
    """

    def __init__(self, name: str, batch: int, ctx: int, n_heads: int,
                 n_kv: int, head_dim: int, count: int, *, window: int = 0,
                 resident_ctx: int = 0):
        self.name = name
        self.batch = batch
        self.ctx = ctx
        self.n_heads = n_heads
        self.n_kv = n_kv
        self.head_dim = head_dim
        self.count = count          # layers sharing this site
        self.window = window
        self.local = window > 0
        self.eff_ctx = min(window, ctx) if window else ctx
        # Tokens actually RESIDENT in the pool for this site. Pages are
        # shared across layers, so a local layer's dead blocks are only
        # freed when every layer is local (Scheduler.trim_window); next to
        # any global layer they stay resident and must be priced at full
        # context even though the walk (latency) only reads the window.
        self.resident_ctx = resident_ctx or self.eff_ctx
        # QuantSite-compatible state features for the DDPG agent
        self.d_in = n_kv * head_dim
        self.d_out = self.eff_ctx
        self.cost: OpCost = self._cost(16)

    def _cost(self, kv_bits: int) -> OpCost:
        return attention_cost(self.batch, 1, self.ctx, self.n_heads,
                              self.n_kv, self.head_dim, window=self.window,
                              decode=True, kv_bits=kv_bits)

    def latency(self, hw, w_bits, a_bits=16) -> float:
        return float(self._cost(int(w_bits)).latency(hw)) * self.count

    def energy(self, hw, w_bits, a_bits=16) -> float:
        return float(self._cost(int(w_bits)).energy(hw)) * self.count

    def size_bytes(self, w_bits) -> float:
        """Resident KV bytes at this batch/context (codes + scale tiles)."""
        toks = self.batch * self.resident_ctx
        bytes_tok = 2.0 * self.n_kv * self.head_dim * int(w_bits) / 8.0
        if int(w_bits) < 16:
            bytes_tok += 2.0 * self.n_kv * 4.0
        return toks * bytes_tok * self.count


def enumerate_kv_sites(cfg, batch: int, ctx: int) -> List[KVCacheSite]:
    """One KVCacheSite per sub-layer slot of the serving pool — the KV
    analogue of enumerate_sites, matching the pool pytree's ``sub{j}`` keys
    (models/transformer.py::pool_specs) so a searched policy maps directly
    onto the quantized page-pool layout."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"KV sites describe attention page pools; {cfg.family!r} "
            f"families have no paged KV cache")
    # the pool pytree's own period/kind rules — deferred import keeps core
    # free of a hard models dependency (models never imports core)
    from repro.models.transformer import period_of, sublayer_kinds
    P = period_of(cfg)
    kinds = sublayer_kinds(cfg)
    n_groups = cfg.num_layers // P
    all_local = all(k["attn"] == "local" for k in kinds)
    sites = []
    for j in range(P):
        window = cfg.window_size if kinds[j]["attn"] == "local" else 0
        sites.append(KVCacheSite(
            f"kv_sub{j}", batch, ctx, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, n_groups, window=window,
            # window-trimmed residency only exists on all-local models
            resident_ctx=0 if all_local else ctx))
    return sites


def resource(sites, wa: List[Tuple[int, int]], hw: Hardware,
             mode: str) -> float:
    if mode == "latency":
        return sum(s.latency(hw, w, a) for s, (w, a) in zip(sites, wa))
    if mode == "energy":
        return sum(s.energy(hw, w, a) for s, (w, a) in zip(sites, wa))
    return sum(s.size_bytes(w) for s, (w, _) in zip(sites, wa))


def enforce_budget(sites, wa: List[Tuple[int, int]], hw: Hardware,
                   budget: float, mode: str) -> List[Tuple[int, int]]:
    """Paper's back-off: sequentially decrement bitwidths until it fits."""
    wa = list(wa)
    guard = 0
    while resource(sites, wa, hw, mode) > budget and guard < 10_000:
        # decrement the site with the largest resource contribution that can
        # still go lower (sequential sweep, as in the paper)
        changed = False
        for i in range(len(wa)):
            w, a = wa[i]
            if a > min(A_BITS):
                wa[i] = (w, A_BITS[A_BITS.index(a) - 1])
                changed = True
            elif w > min(W_BITS):
                wa[i] = (w - 1, a)
                changed = True
            if changed and resource(sites, wa, hw, mode) <= budget:
                return wa
        if not changed:
            break
        guard += 1
    return wa


class HAQEnv:
    def __init__(self, cfg, sites: List[QuantSite],
                 eval_policy: Callable[[Dict[str, Tuple[int, int]]], float],
                 hcfg: HAQConfig, hw: Hardware = V5E_EDGE):
        self.cfg = cfg
        self.sites = sites
        self.eval_policy = eval_policy
        self.hcfg = hcfg
        self.hw = hw
        base = [(8, 8)] * len(sites)
        self.base_resource = resource(sites, base, hw, hcfg.mode)
        self.budget = hcfg.latency_budget or hcfg.budget_frac * \
            self.base_resource
        self.base_loss = float(eval_policy({s.name: (16, 16)
                                            for s in sites}))

    def state(self, t: int, prev_w: int, prev_a: int) -> np.ndarray:
        s = self.sites[t]
        return np.array([
            t / max(len(self.sites) - 1, 1),
            np.log10(max(float(s.cost.flops), 1.0)) / 15.0,
            np.log10(max(float(s.cost.weight_bytes), 1.0)) / 12.0,
            float(s.cost.intensity()) / 1000.0,
            s.d_in / 16384.0,
            s.d_out / 16384.0,
            s.count / 100.0,
            prev_w / 8.0,
            prev_a / 16.0,
            self.budget / max(self.base_resource, 1e-12),
        ], np.float32)

    def decode_action(self, a: float, arr) -> int:
        idx = int(round(a * (len(arr) - 1)))
        return arr[max(0, min(idx, len(arr) - 1))]

    def rollout(self, agent_w: DDPG, agent_a: DDPG, explore=True) -> dict:
        wa: List[Tuple[int, int]] = []
        traj = []
        pw, pa = 8, 8
        for t in range(len(self.sites)):
            s = self.state(t, pw, pa)
            aw = agent_w.act(s, explore=explore)
            aa = agent_a.act(s, explore=explore)
            w_bits = self.decode_action(aw, W_BITS)
            a_bits = self.decode_action(aa, A_BITS)
            wa.append((w_bits, a_bits))
            traj.append((s, aw, aa))
            pw, pa = w_bits, a_bits
        wa = enforce_budget(self.sites, wa, self.hw, self.budget,
                            self.hcfg.mode)
        policy = {s.name: b for s, b in zip(self.sites, wa)}
        loss = float(self.eval_policy(policy))
        reward = -self.hcfg.quality_coef * (loss - self.base_loss)
        for t, (s, aw, aa) in enumerate(traj):
            done = t == len(traj) - 1
            s2 = self.state(min(t + 1, len(self.sites) - 1), *wa[t]) \
                if not done else np.zeros(STATE_DIM, np.float32)
            r = reward if done else 0.0
            agent_w.observe(s, aw, r, s2, done)
            agent_a.observe(s, aa, r, s2, done)
        used = resource(self.sites, wa, self.hw, self.hcfg.mode)
        return {"policy": policy, "loss": loss, "reward": reward,
                "resource": used, "budget": self.budget,
                "base_resource": self.base_resource}


def search(cfg, sites, eval_policy, hcfg: HAQConfig = HAQConfig(),
           hw: Hardware = V5E_EDGE,
           agents: Optional[Tuple[DDPG, DDPG]] = None,
           progress: Optional[Callable[[dict], None]] = None) -> dict:
    """Returns best policy + history (+ the trained agents for Table 7's
    transfer experiment)."""
    env = HAQEnv(cfg, sites, eval_policy, hcfg, hw)
    if agents is None:
        agent_w = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=hcfg.seed)
        agent_a = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=hcfg.seed + 1)
    else:
        agent_w, agent_a = agents
    best, hist = None, []
    for ep in range(hcfg.episodes):
        rec = env.rollout(agent_w, agent_a, explore=True)
        agent_w.end_episode()
        agent_a.end_episode()
        hist.append({"episode": ep, "loss": rec["loss"],
                     "reward": rec["reward"], "resource": rec["resource"]})
        if best is None or rec["reward"] > best["reward"]:
            best = rec
        if progress and ep % 10 == 0:
            progress(rec)
    final = env.rollout(agent_w, agent_a, explore=False)
    if final["reward"] > best["reward"]:
        best = final
    return {"best": best, "history": hist, "base_loss": env.base_loss,
            "agents": (agent_w, agent_a),
            "sites": [s.name for s in env.sites]}
