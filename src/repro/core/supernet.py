"""Path-level binarized supernet (paper §2 / ProxylessNAS), LM-adapted.

Each of the N blocks holds 7 candidate ops (configs/supernet_lm.py). During
search, exactly ONE path per block is active (Eq. 1: x_l = sum_i g_i o_i(x),
g ~ Multinomial(softmax(alpha))) — implemented with `lax.switch`, so only the
sampled op's compute graph executes: the paper's GPU-hours/GPU-memory saving
("path-level binarization") maps directly to jit-time dead-path elimination.

Gradient estimator: the sampled path's output is scaled by
(p_i - stop_grad(p_i) + 1), the straight-through estimator of the paper's
∂L/∂α_i ≈ Σ_j ∂L/∂g_j ∂p_j/∂α_i with the sampled g as the evaluation point.
The latency term (Eq. 2/3) uses the full softmax, so every α receives a dense
hardware-cost gradient each step even though only one path computes.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.supernet_lm import BACKBONE, CANDIDATE_OPS
from repro.models import attention as attn
from repro.models import ssm as ssm_lib
from repro.models.layers import ffn_apply, ffn_defs, norm_def, rms_norm
from repro.models.params import PDef, init_params
from repro.models.transformer import embed_tokens, chunked_ce

F32 = jnp.float32

OP_SPECS = {
    "attn_full_e2": dict(kind="global", window=0, expand=2, arm="attn"),
    "attn_full_e4": dict(kind="global", window=0, expand=4, arm="attn"),
    "attn_local1k_e2": dict(kind="local", window=1024, expand=2, arm="attn"),
    "attn_local1k_e4": dict(kind="local", window=1024, expand=4, arm="attn"),
    "attn_local4k_e4": dict(kind="local", window=4096, expand=4, arm="attn"),
    "mamba2_e2": dict(arm="ssm"),
    "zero": dict(arm="zero"),
}


# ------------------------------------------------------------ parameters ----
def _op_defs(cfg, op: str) -> Dict[str, Any]:
    spec = OP_SPECS[op]
    d = cfg.d_model
    if spec["arm"] == "zero":
        return {"_": PDef((1,), ("null",), "zeros")}
    if spec["arm"] == "ssm":
        return {"ln": norm_def(d), "mamba": ssm_lib.mamba_defs(cfg)}
    return {
        "ln1": norm_def(d),
        "attn": attn.attn_defs(d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim),
        "ln2": norm_def(d),
        "ffn": ffn_defs(d, spec["expand"] * d, cfg.activation),
    }


def supernet_defs(cfg=BACKBONE) -> Dict[str, Any]:
    blocks = []
    for i in range(cfg.num_layers):
        blocks.append({op: _op_defs(cfg, op) for op in CANDIDATE_OPS})
    return {
        "embed": PDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                      "normal"),
        "blocks": blocks,  # python list: per-block independent params
        "final_norm": norm_def(cfg.d_model),
        "lm_head": PDef((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                        "scaled"),
    }


def init_supernet(key, cfg=BACKBONE):
    params = init_params(supernet_defs(cfg), key)
    alpha = jnp.zeros((cfg.num_layers, len(CANDIDATE_OPS)), F32)
    return params, alpha


# ----------------------------------------------------------------- apply ----
def _apply_op(op: str, p, x, cfg, positions):
    spec = OP_SPECS[op]
    if spec["arm"] == "zero":
        return x * 1.0
    if spec["arm"] == "ssm":
        y, _ = ssm_lib.mamba_block_fwd(p["mamba"],
                                       rms_norm(x, p["ln"], cfg.norm_eps), cfg)
        return x + y
    sub_cfg = cfg.replace(window_size=spec["window"] or cfg.window_size)
    a, _ = attn.attention_fwd(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                              spec["kind"], sub_cfg, positions)
    x = x + a
    f = ffn_apply(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                  cfg.activation)
    return x + f


def supernet_forward(params, alpha, gates, batch, cfg=BACKBONE):
    """gates: (N,) int32 sampled op index per block (path binarization).

    Returns final hidden states; CE computed by the caller (chunked)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    probs = jax.nn.softmax(alpha, axis=-1)

    for i, block in enumerate(params["blocks"]):
        branches = [
            (lambda p=block[op], op=op:
             lambda xx: _apply_op(op, p, xx, cfg, positions))()
            for op in CANDIDATE_OPS
        ]
        y = jax.lax.switch(gates[i], branches, x)
        # straight-through: scale by (p - sg(p) + 1) so dL/dalpha_i flows
        p_i = probs[i, gates[i]]
        x = y * (p_i - jax.lax.stop_gradient(p_i) + 1.0)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x


def supernet_loss(params, alpha, gates, batch, cfg=BACKBONE):
    hidden = supernet_forward(params, alpha, gates, batch, cfg)
    return chunked_ce(params, hidden, batch["labels"], cfg)


def sample_gates(key, alpha) -> jax.Array:
    """Multinomial path sampling per block (Eq. 1's g)."""
    return jax.random.categorical(key, alpha, axis=-1)


def derive_arch(alpha) -> List[str]:
    """argmax op per block — the specialized child architecture."""
    idx = jnp.argmax(alpha, axis=-1)
    return [CANDIDATE_OPS[int(i)] for i in idx]


def child_param_count(arch: List[str], cfg=BACKBONE) -> int:
    from repro.models.params import param_count
    total = param_count({"e": PDef((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"))})
    total *= 2  # embed + head
    for op in arch:
        defs = _op_defs(cfg, op)
        total += param_count(defs) if OP_SPECS[op]["arm"] != "zero" else 0
    return total
