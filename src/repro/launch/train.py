"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On the CPU container this trains tiny/reduced configs end-to-end (see
examples/train_lm.py for the ~100M run); on a real pod the same entry point
drives the production mesh — the mesh/sharding logic is shared with the
dry-run, so what compiles there runs here.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import (OptimConfig, TrainConfig, get_config, get_shape,
                           tiny_config)
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig
from repro.models.api import build_model
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    shape = get_shape(args.shape)
    if args.batch or args.seq:
        shape = ShapeConfig(shape.name, args.seq or shape.seq_len,
                            args.batch or shape.global_batch, shape.kind)
    model = build_model(cfg)
    tcfg = TrainConfig(
        optim=OptimConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1)),
        checkpoint_dir=f"{args.ckpt_dir}/{cfg.name}",
        checkpoint_every=args.ckpt_every,
        microbatches=args.microbatches,
        log_every=5,
    )
    print(f"training {cfg.name}: {model.param_count():,} params, "
          f"shape=({shape.global_batch}x{shape.seq_len}), "
          f"devices={jax.device_count()}")
    out = train(model, shape, tcfg, num_steps=args.steps,
                dcfg=DataConfig(cfg.vocab_size, shape.seq_len,
                                shape.global_batch))
    first, last = out["history"][0], out["history"][-1]
    print(f"loss {first['loss']} -> {last['loss']} over "
          f"{args.steps} steps; straggler events: "
          f"{len(out['straggler_events'])}")


if __name__ == "__main__":
    main()
