import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory_analysis / cost_analysis / collective schedule, and
derive the three-term roofline (repro.roofline.analysis).

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
Results are cached to artifacts/dryrun/<arch>__<shape>__<mesh>.json; pass
--force to recompute a cell.
"""
import argparse
import gc
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (SHAPES, TrainConfig, OptimConfig, assigned_cells,
                           get_config, get_shape)
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.roofline import analysis as ra
from repro.training import steps as steps_lib

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# archs whose optimizer state only fits 16GiB/chip with int8 Adam moments
QUANT_MOMENT_ARCHS = {"llama4-maverick-400b-a17b", "mistral-large-123b"}


def train_cfg_for(arch: str, microbatches: int = 1) -> TrainConfig:
    return TrainConfig(optim=OptimConfig(
        quantized_moments=arch in QUANT_MOMENT_ARCHS),
        microbatches=microbatches)


def quant_policy_for(cfg, mode: str):
    """HAQ-style decode policy via the paper's budget back-off (§4) on the
    TPU hardware model — deterministic stand-in for the trained agent."""
    from repro.core import haq
    from repro.core.hardware_model import V5E_POD
    if mode == "w8":
        return None, 8
    if mode == "w4":
        return None, 4
    sites = haq.enumerate_sites(cfg, batch=128, seq=1, decode=True)
    wa = [(8, 16)] * len(sites)
    budget = 0.55 * haq.resource(sites, wa, V5E_POD, "latency")
    wa = haq.enforce_budget(sites, wa, V5E_POD, budget, "latency")
    return {s.name: w for s, (w, a) in zip(sites, wa)}, 8


def build_step(model, shape, mesh, tcfg, quant: str = "", ac_mode: str = "dp"):
    """Returns (fn, args_abstract, in_shardings, out_shardings, donate)."""
    from repro.models.params import abstract_params, logical_specs
    from repro.serving import quant as sq

    ac = shlib.make_ac(mesh, mode=ac_mode)
    cfg = model.cfg
    dot = None
    p_abstract = model.abstract_params()
    p_logical = model.logical_specs()
    weight_bits = 16.0
    if quant and shape.kind != "train":
        policy, default_bits = quant_policy_for(cfg, quant)
        defs_q = sq.quantize_defs(model.defs, policy=policy,
                                  default_bits=default_bits)
        p_abstract = abstract_params(defs_q)
        p_logical = logical_specs(defs_q)
        dot = sq.dequant_dot
        weight_bits = sq.avg_weight_bits(defs_q)
    pspecs = shlib.specs_for(p_abstract, p_logical, mesh)
    if shape.kind == "train":
        step = steps_lib.make_train_step(model, tcfg, ac=ac)
        state = steps_lib.abstract_train_state(model, tcfg)
        sspecs = shlib.specs_for(
            state, steps_lib.train_state_logical_specs(model, tcfg), mesh)
        batch = model.input_specs(shape)
        bspecs = shlib.specs_for(batch, model.batch_logical_specs(shape), mesh)
        scal = shlib.scalar_sharding(mesh)
        metrics = {"loss": scal, "lr": scal, "grad_norm": scal}
        return (step, (state, batch), (sspecs, bspecs), (sspecs, metrics),
                (0,), weight_bits)
    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(model, ac=ac, dot=dot)
        batch = model.input_specs(shape)
        bspecs = shlib.specs_for(batch, model.batch_logical_specs(shape), mesh)
        cache_ax = model.batch_logical_specs(
            SHAPES["decode_32k"])["cache"]
        cspecs = shlib.specs_for(model.cache_specs(shape.global_batch,
                                                   shape.seq_len),
                                 cache_ax, mesh)
        return (step, (p_abstract, batch), (pspecs, bspecs), (None, cspecs),
                (), weight_bits)
    # decode
    step = steps_lib.make_serve_step(model, ac=ac, dot=dot)
    ins = model.input_specs(shape)
    inspecs = shlib.specs_for(ins, model.batch_logical_specs(shape), mesh)
    return (step,
            (p_abstract, ins["cache"], ins["token"], ins["pos"]),
            (pspecs, inspecs["cache"], inspecs["token"], inspecs["pos"]),
            (None, inspecs["cache"]),
            (1,), weight_bits)


def sharded_bytes_per_device(abstract, shardings) -> int:
    """Exact persistent per-device bytes for a (state/cache) pytree under its
    NamedShardings — the number that decides HBM fit on real v5e chips. The
    compiled CPU memory_analysis over-reports bf16 buffers (XLA:CPU legalizes
    bf16 compute to f32) — see EXPERIMENTS.md §Dry-run."""
    total = 0
    for a, s in zip(jax.tree.leaves(abstract), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "shard_shape"))):
        local = s.shard_shape(a.shape)
        n = 1
        for d in local:
            n *= d
        total += n * a.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save_hlo=False,
             out_dir: Path = ART, tag: str = "", quant: str = "",
             microbatches: int = 1, ac_mode: str = "dp") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    model = build_model(cfg)
    tcfg = train_cfg_for(arch, microbatches)

    t0 = time.time()
    step, args, in_sh, out_sh, donate, weight_bits = build_step(
        model, shape, mesh, tcfg, quant=quant, ac_mode=ac_mode)
    state_bytes = sharded_bytes_per_device(args[0], in_sh[0])
    if shape.kind == "decode":  # + cache
        state_bytes += sharded_bytes_per_device(args[1], in_sh[1])
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = ra.analyze_hlo_aware(
        hlo, chips, cfg, shape, weight_bits=weight_bits,
        quantized_moments=tcfg.optim.quantized_moments)
    roof_raw = ra.analyze(compiled, chips, cfg, shape, hlo_text=hlo)
    coll = ra.collective_bytes(hlo)
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
    }
    live = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0) \
        - (mem["alias_bytes"] or 0)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips,
        "params": model.param_count(),
        "active_params": ra.active_params(cfg),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "live_bytes_per_device": live,
        "state_bytes_per_device": state_bytes,
        "fits_16GiB": bool(live <= ra.HBM_GB * (1 << 30)),
        "state_fits_16GiB": bool(state_bytes <= ra.HBM_GB * (1 << 30)),
        "collectives_per_device": {k: v for k, v in coll.items() if v},
        "roofline": roof.to_dict(),
        "roofline_raw_xla": roof_raw.to_dict(),
        "hlo_chars": len(hlo),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}{tag}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (out_dir / f"{name}.hlo.txt").write_text(hlo)
    del compiled, lowered, hlo
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--quant", default="", choices=["", "w8", "w4", "haq"],
                    help="quantized-weight serving (prefill/decode cells)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient accumulation for train cells")
    ap.add_argument("--ac-mode", default="dp", choices=["dp", "seq_tp"],
                    help="activation sharding: dp | seq_tp (sequence-parallel TP)")
    args = ap.parse_args()

    cells = assigned_cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch, shape in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape}__{mesh_kind}{args.tag}"
            path = ART / f"{name}.json"
            if path.exists() and not args.force:
                rec = json.loads(path.read_text())
                print(f"[cached] {name}: {rec['roofline']['bottleneck']}-bound"
                      f" live={rec['live_bytes_per_device']/2**30:.2f}GiB")
                continue
            try:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind,
                               save_hlo=args.save_hlo, tag=args.tag,
                               quant=args.quant,
                               microbatches=args.microbatches,
                               ac_mode=args.ac_mode)
                r = rec["roofline"]
                print(f"[ok {time.time()-t0:6.1f}s] {name}: "
                      f"comp={r['t_compute_s']:.4f}s "
                      f"mem={r['t_memory_s']:.4f}s "
                      f"coll={r['t_collective_s']:.4f}s "
                      f"{r['bottleneck']}-bound "
                      f"live={rec['live_bytes_per_device']/2**30:.2f}GiB "
                      f"state={rec['state_bytes_per_device']/2**30:.2f}GiB "
                      f"fits={rec['fits_16GiB']}", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((name, repr(e)))
                print(f"[FAIL] {name}: {e!r}", flush=True)
                traceback.print_exc()
            jax.clear_caches()
            gc.collect()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
