"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever-devices-exist mesh for CPU smoke tests / examples."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
