"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across the pinned jax range: newer jax wants explicit
    Auto axis types; 0.4.x has neither the kwarg nor the enum (every axis
    is implicitly auto there)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever-devices-exist mesh for CPU smoke tests / examples."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return _mesh((n // model, model), ("data", "model"))


def make_serving_mesh(model: int = 1, data: int = 1):
    """Explicit-size ("data", "model") mesh for the SPMD serving engine
    (serving/engine/sharded.py). Sizes are taken literally — the engine's
    exactness contract depends on them — and must fit the visible devices
    (force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` off-TPU)."""
    if model < 1 or data < 1:
        raise ValueError(f"mesh axes must be >= 1, got model={model} "
                         f"data={data}")
    n = jax.device_count()
    if model * data > n:
        raise ValueError(
            f"serving mesh model={model} x data={data} needs "
            f"{model * data} devices, have {n} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={model * data} for a "
            f"host-device mesh)")
    return _mesh((data, model), ("data", "model"))
