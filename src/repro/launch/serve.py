"""Serving launcher — a thin CLI over the continuous-batching engine
(serving/engine), with the sequential batched generate kept as the
reference baseline for equivalence tests and throughput comparisons.

``python -m repro.launch.serve --arch gemma2-2b --tiny --requests 8``
``python -m repro.launch.serve --arch gemma2-2b --tiny --sequential``
``python -m repro.launch.serve --arch gemma2-2b --tiny --kv-bits 8``
``python -m repro.launch.serve --arch gemma2-2b --tiny --kv-policy haq``
``XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.serve --arch gemma2-2b --tiny --mesh model=2,data=4``
``python -m repro.launch.serve --arch gemma2-2b --tiny \\
  --autotune 64 --autotune-out SERVING_gemma2.json``
``python -m repro.launch.serve --arch gemma2-2b --tiny \\
  --serving-config SERVING_gemma2.json``
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.hardware_model import HARDWARES
from repro.core.quantization import make_quant_dot
from repro.models.api import build_model
from repro.serving.engine import Engine, Request, derive_policy
from repro.serving.engine.pool import quiet_donation

# decode closures are cached per (cfg, dot) so repeated generate() calls —
# one per request in the sequential baseline — reuse one jitted function
# instead of retracing every call. Values hold the dot hook alive so id()
# keys can't be recycled.
_DECODE_JIT: Dict[Tuple, Tuple] = {}


def _decode_fn(model, dot, kernel="auto"):
    paged = model.cfg.family in ("dense", "moe", "vlm") \
        and not model.cfg.is_encdec
    key = (model.cfg, None if dot is None else id(dot), paged, kernel)
    ent = _DECODE_JIT.get(key)
    if ent is None:
        if paged:
            fn = jax.jit(lambda p, pool, pt, t, pos: model.decode_step_paged(
                p, pool, pt, t, pos, dot=dot, kernel=kernel),
                donate_argnums=(1,))
        else:
            fn = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                                dot=dot))
        ent = (fn, dot)
        _DECODE_JIT[key] = ent
    return ent[0], paged


def _identity_paged_pool(cache, B: int, max_len: int, page: int):
    """Scatter a full-layout prefill cache into a fresh identity-mapped page
    pool: sequence b's logical block i lives at physical page 1 + b*ppseq
    + i (page 0 stays the scratch page, as in the engine)."""
    ppseq = -(-max_len // page)
    span = ppseq * page
    pt = np.arange(B * ppseq, dtype=np.int32).reshape(B, ppseq) + 1

    def to_pages(c):                     # (G, B, S, K, hd) full layout
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, span - c.shape[2])
        c = jnp.pad(c, pad)
        c = c.reshape(c.shape[0], B * ppseq, page, *c.shape[3:])
        pool = jnp.zeros((c.shape[0], B * ppseq + 1) + c.shape[2:], c.dtype)
        return pool.at[:, 1:].set(c)

    return jax.tree.map(to_pages, cache), jnp.asarray(pt)


def generate(model, params, prompt_tokens, gen_len: int, *, temperature=0.0,
             dot=None, key=None, page_size: int = 16, kernel: str = "auto"):
    """prompt (B, S) -> (B, S+gen_len).

    Sequential baseline: one fixed batch, no admission — the engine's
    continuous batching supersedes this for traffic; kept as the exactness
    reference. Decode runs the same paged-attention walk as the engine over
    an identity page table (block i of sequence b at page 1 + b*ppseq + i,
    ``page_size`` matching the default admission policy), so the reduction
    order — and therefore every greedy token — is bit-comparable with the
    engine regardless of batch composition, growth, or preemption. The
    paged walk itself is validated against the dense oracle in
    tests/test_kernels.py; dense ring-buffer decode stays covered by
    tests/test_decode_equivalence.py.

    Families the engine does not serve (ssm / hybrid / encdec) fall back to
    the dense-cache ``decode_step`` path."""
    B, S = prompt_tokens.shape
    max_len = S + gen_len
    decode, paged = _decode_fn(model, dot, kernel)

    logits, cache = model.prefill(params, {"tokens": prompt_tokens}, dot=dot,
                                  cache_layout="full")
    if paged:
        pool, pt = _identity_paged_pool(cache, B, max_len, page_size)
    else:
        cache = _grow_cache(model, cache, S, max_len)

    out = [prompt_tokens]
    tok = _sample(logits, temperature, key)
    for i in range(gen_len):
        out.append(tok)
        if i == gen_len - 1:
            break
        if paged:
            positions = jnp.full((B,), S + i, jnp.int32)
            with quiet_donation():
                logits, pool = decode(params, pool, pt, tok, positions)
        else:
            logits, cache = decode(params, cache, tok,
                                   jnp.asarray(S + i, jnp.int32))
        if key is not None:
            key = jax.random.fold_in(key, i)
        tok = _sample(logits, temperature, key)
    return jnp.concatenate(out, axis=1)


def _grow_cache(model, cache, cur: int, max_len: int):
    """Pad dense KV caches from prefill length to max_len (the non-paged
    family fallback)."""
    def grow(path, a):
        ks = jax.tree_util.keystr(path)
        if a.ndim == 5 and "mamba" not in ks and a.shape[2] == cur:
            pad = [(0, 0)] * 5
            pad[2] = (0, max_len - cur)
            return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map_with_path(grow, cache)


def _sample(logits, temperature, key):
    logits = logits[:, -1]
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature)[:, None] \
        .astype(jnp.int32)


def _parse_mesh(spec: str) -> Dict[str, int]:
    """'model=2' / 'model=2,data=4' -> axis sizes (missing axes = 1)."""
    sizes = {"model": 1, "data": 1}
    for part in filter(None, spec.split(",")):
        name, _, val = part.partition("=")
        name = name.strip()
        if name not in sizes or not val.strip().isdigit():
            raise ValueError(
                f"bad --mesh entry {part!r}; expected model=N[,data=M]")
        sizes[name] = int(val)
    return sizes


def _make_requests(args, cfg):
    rng = np.random.default_rng(0)
    reqs = []
    lo = min(4, args.prompt_len)
    for i in range(args.requests):
        S = int(rng.integers(lo, args.prompt_len + 1))
        prompt = rng.integers(2, cfg.vocab_size, S).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.gen))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--hw", default="v5e-1chip", choices=sorted(HARDWARES))
    ap.add_argument("--requests", type=int, default=8,
                    help="engine mode: number of requests in the trace")
    ap.add_argument("--batch", type=int, default=4,
                    help="sequential mode: fixed batch size")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="override the policy's max in-flight batch")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV pool page size in tokens (both modes)")
    ap.add_argument("--paged-kernel", default="auto",
                    choices=("auto", "pallas", "ref"),
                    help="paged-attention path: Pallas page-walk kernel, "
                         "pure-JAX block walk, or auto (Pallas on TPU)")
    ap.add_argument("--reserve-upfront", action="store_true",
                    help="legacy admission: reserve every page of "
                         "prompt+max_new at admission instead of growing "
                         "lazily with preemption")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine mode: override the policy's roofline-"
                         "derived prompt chunk (tokens per prefill tick; "
                         "0 keeps the derived value)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="engine mode: prefill whole prompts into padding "
                         "buckets in one forward (the pre-chunking "
                         "behaviour — one long prompt stalls every "
                         "resident decode for its full prefill latency)")
    ap.add_argument("--expected-occupancy", type=float, default=None,
                    help="fraction of max_model_len the admission policy "
                         "assumes a typical sequence occupies (default "
                         "0.5, or 1.0 with --reserve-upfront: worst-case "
                         "reservation can never fill slots an expected-"
                         "footprint batch was sized for)")
    ap.add_argument("--sequential", action="store_true",
                    help="legacy fixed-batch loop instead of the engine")
    ap.add_argument("--quant-policy", default="",
                    help="json file: {site: [w_bits, a_bits]} "
                         "(sequential mode only)")
    ap.add_argument("--kv-bits", type=int, default=16,
                    choices=(4, 8, 16),
                    help="engine mode: stored KV-cache bits for the paged "
                         "pool, uniform across layers (16 = bf16 exact "
                         "baseline; 8/4 = serving/kvquant int pages with "
                         "per-token per-head scales, dequant fused into "
                         "the paged-attention walk)")
    ap.add_argument("--mesh", default="",
                    help="engine mode: SPMD serving over a device mesh, "
                         "e.g. 'model=2' or 'model=2,data=4' — the paged "
                         "pool shards kv_heads over the model axis, params "
                         "spread at rest over the whole mesh, outputs stay "
                         "token-identical to the 1-device engine (off-TPU "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first)")
    ap.add_argument("--trace-out", default="",
                    help="engine mode: write the telemetry tick trace + "
                         "request spans as Chrome trace-event JSON to this "
                         "path (open in Perfetto / chrome://tracing) and "
                         "print the telemetry summary")
    ap.add_argument("--serving-config", default="",
                    help="engine mode: load a searched per-hardware "
                         "serving config JSON (serving/autotune, written "
                         "by --autotune-out or the bench's "
                         "--autotune-config-out) instead of hand-picking "
                         "knobs; owns page size, prefill chunk, occupancy, "
                         "KV policy, mesh split, and the batch cap")
    ap.add_argument("--autotune", type=int, default=0, metavar="BUDGET",
                    help="engine mode: autotune the serving config before "
                         "serving — calibrate the admission roofline on a "
                         "warmup run, search the config space "
                         "(DDPG + evolution, serving/autotune) with this "
                         "many objective evaluations, validate the top "
                         "candidates on the real engine, and serve the "
                         "trace with the measured winner (0 = off)")
    ap.add_argument("--autotune-out", default="",
                    help="with --autotune: write the searched serving "
                         "config JSON here for --serving-config to load "
                         "back ('' disables)")
    ap.add_argument("--kv-policy", default="",
                    help="engine mode: per-layer KV bit policy — 'haq' "
                         "runs the HAQ search over KV sites "
                         "(serving/kvquant/policy.py: roofline feedback, "
                         "sensitivity-gated int4), or a json file mapping "
                         "sub-layer slots to bits, e.g. "
                         "'{\"sub0\": 4, \"sub1\": 8}'. Overrides "
                         "--kv-bits")
    args = ap.parse_args()
    if args.prompt_len < 1:
        ap.error("--prompt-len must be >= 1")
    if args.quant_policy and not args.sequential:
        ap.error("--quant-policy applies to --sequential mode only; the "
                 "engine derives its quantization from the admission policy")
    if args.sequential and (args.kv_policy or args.kv_bits != 16):
        ap.error("--kv-bits/--kv-policy apply to engine mode only; the "
                 "sequential baseline is the fp exactness reference")
    if args.sequential and args.mesh:
        ap.error("--mesh applies to engine mode only; the sequential "
                 "baseline is the single-device exactness reference")
    if args.sequential and args.trace_out:
        ap.error("--trace-out applies to engine mode only; the sequential "
                 "baseline has no telemetry recorder")
    if args.sequential and (args.autotune or args.serving_config):
        ap.error("--autotune/--serving-config apply to engine mode only; "
                 "the sequential baseline has no admission policy to tune")
    if args.autotune and args.serving_config:
        ap.error("--serving-config loads a finished search; drop it or "
                 "drop --autotune")
    if args.autotune_out and not args.autotune:
        ap.error("--autotune-out only makes sense with --autotune")
    if (args.autotune or args.serving_config) and (
            args.kv_policy or args.kv_bits != 16 or args.mesh):
        ap.error("--kv-bits/--kv-policy/--mesh are knobs the serving "
                 "config owns; drop them when using "
                 "--autotune/--serving-config")

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.sequential:
        dot = None
        if args.quant_policy:
            policy = {k: tuple(v) for k, v in
                      json.load(open(args.quant_policy)).items()}
            dot = make_quant_dot(policy)
            print(f"serving with quantization policy over "
                  f"{len(policy)} sites")
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(
                2, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        t0 = time.time()
        out = generate(model, params, prompt, args.gen,
                       temperature=args.temperature, dot=dot,
                       page_size=args.page_size, kernel=args.paged_kernel,
                       key=jax.random.PRNGKey(1)
                       if args.temperature > 0 else None)
        dt = time.time() - t0
        print(f"{cfg.name}: generated {args.gen} tokens x batch "
              f"{args.batch} in {dt:.2f}s "
              f"({args.gen * args.batch / dt:.1f} tok/s)")
        print("sample:",
              np.asarray(out[0, args.prompt_len:args.prompt_len + 16]))
        return

    hw = HARDWARES[args.hw]
    max_len = args.prompt_len + args.gen
    occupancy = args.expected_occupancy
    if occupancy is None:
        occupancy = 1.0 if args.reserve_upfront else 0.5

    reqs = _make_requests(args, cfg)

    if args.serving_config or args.autotune:
        # the serving config owns every knob the flags below would set;
        # the incompatible-flag combinations already errored above
        from repro.serving.autotune import (ConfigSpace,
                                            autotune_serving_config,
                                            load_serving_config,
                                            save_serving_config)
        space = ConfigSpace(cfg, hw, max_model_len=max_len,
                            max_devices=jax.device_count(),
                            max_batch_cap=args.max_batch or 8,
                            param_bytes=model.param_bytes())
        if args.serving_config:
            sc, record = load_serving_config(args.serving_config)
            if (record.get("arch"), record.get("hw"),
                    record.get("max_model_len")) != \
                    (cfg.name, hw.name, max_len):
                print(f"serving-config: note — searched for "
                      f"{record.get('arch')}@{record.get('max_model_len')} "
                      f"on {record.get('hw')}, serving "
                      f"{cfg.name}@{max_len} on {hw.name}")
            print(f"serving-config[{record.get('hw')}]: {sc.as_dict()}")
        else:
            t0 = time.time()
            tune = autotune_serving_config(model, params, space, reqs,
                                           budget=args.autotune, seed=0)
            sc = tune.winner.scored.config
            corr = tune.rank_correlation
            print(f"autotune[{hw.name}]: {tune.search.evaluated} "
                  f"candidates ({tune.search.admissible} admissible) in "
                  f"{time.time() - t0:.1f}s -> "
                  f"{tune.winner.decode_tok_s:.1f} decode tok/s vs "
                  f"default {tune.default.decode_tok_s:.1f} "
                  f"({tune.searched_vs_default:.2f}x), rank corr "
                  + ("n/a" if corr is None else f"{corr:.2f}"))
            print(f"autotune[{hw.name}]: winner {sc.as_dict()}")
            if args.autotune_out:
                save_serving_config(args.autotune_out, tune.record(space))
                print(f"autotune: wrote {args.autotune_out} "
                      f"(load with --serving-config)")
        bad = space.violations(sc)
        if bad:
            ap.error(f"serving config not admissible for {cfg.name}@"
                     f"{max_len} on {hw.name}: {'; '.join(bad)}")
        policy = space.to_policy(sc)
        mesh = None
        if sc.mesh_model > 1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(model=sc.mesh_model, data=1)
    else:
        kv_bits = None if args.kv_bits == 16 else args.kv_bits
        if args.kv_policy == "haq":
            from repro.serving.kvquant import search_kv_policy
            res = search_kv_policy(cfg, hw, max_model_len=max_len,
                                   episodes=8)
            kv_bits = res["bits"]
            print(f"kvquant[haq]: {res['policy']} "
                  f"({res['kv_bytes_per_token_fp']}->"
                  f"{res['kv_bytes_per_token']} B/token)")
        elif args.kv_policy:
            from repro.models.transformer import normalize_kv_bits
            kv_bits = normalize_kv_bits(
                cfg, json.load(open(args.kv_policy)))

        mesh = None
        mesh_sizes = {"model": 1, "data": 1}
        if args.mesh:
            from repro.launch.mesh import make_serving_mesh
            try:
                mesh_sizes = _parse_mesh(args.mesh)
                mesh = make_serving_mesh(**mesh_sizes)
            except ValueError as e:
                ap.error(str(e))

        policy = derive_policy(cfg, hw, max_model_len=max_len,
                               page_size=args.page_size,
                               expected_occupancy=occupancy,
                               param_bytes=model.param_bytes(),
                               kv_bits=kv_bits,
                               mesh_model=mesh_sizes["model"],
                               mesh_data=mesh_sizes["data"])
        if args.max_batch or args.prefill_chunk:
            import dataclasses
            over = {}
            if args.max_batch:
                over["max_batch"] = args.max_batch
            if args.prefill_chunk:
                over["prefill_chunk"] = args.prefill_chunk
            policy = dataclasses.replace(policy, **over)
    print(f"admission[{hw.name}]: max_batch={policy.max_batch} "
          f"prefill_chunk={policy.prefill_chunk} "
          f"chunked={not args.no_chunked_prefill} "
          f"quant={policy.quant_bits}b "
          f"kv={policy.kv_bits or 'bf16'} pages={policy.num_pages} "
          f"page_size={policy.page_size} "
          f"mesh=model:{policy.mesh_model},data:{policy.mesh_data} "
          f"(est decode {policy.est_decode_s * 1e3:.2f}ms/step)")
    engine = Engine(model, params, policy, temperature=args.temperature,
                    paged_kernel=args.paged_kernel,
                    reserve_upfront=args.reserve_upfront,
                    chunked_prefill=not args.no_chunked_prefill,
                    mesh=mesh)
    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    gen_total = engine.stats["decode_tokens"] + engine.stats["prefills"]
    print(f"{cfg.name}: served {len(reqs)} requests, {gen_total} tokens in "
          f"{dt:.2f}s ({gen_total / dt:.1f} tok/s, "
          f"{engine.stats['decode_ticks']} decode ticks, "
          f"{engine.stats['prefill_chunks']} prefill chunks, "
          f"{engine.stats['preemptions']} preemptions, "
          f"{engine.stats['grown_pages']} pages grown)")
    first = outs[0]
    print("sample:", first[len(reqs[0].prompt):len(reqs[0].prompt) + 16])
    if args.trace_out:
        from repro.serving.telemetry import summarize, write_chrome_trace
        write_chrome_trace(engine.telemetry, args.trace_out)
        print(f"telemetry: wrote Chrome trace to {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
        print(summarize(engine.telemetry))


if __name__ == "__main__":
    main()
