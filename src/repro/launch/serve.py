"""Serving launcher: batched prefill + decode with KV cache, greedy/temp
sampling, optional HAQ quantization policy.

``python -m repro.launch.serve --arch gemma2-2b --tiny --gen 32``
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.quantization import make_quant_dot
from repro.models.api import build_model


def generate(model, params, prompt_tokens, gen_len: int, *, temperature=0.0,
             dot=None, key=None):
    """prompt (B, S) -> (B, S+gen_len). Grows the cache to S+gen_len."""
    B, S = prompt_tokens.shape
    max_len = S + gen_len
    cfg = model.cfg

    logits, cache = model.prefill(params, {"tokens": prompt_tokens}, dot=dot)
    cache = _grow_cache(model, cache, S, max_len)

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                            dot=dot))
    out = [prompt_tokens]
    tok = _sample(logits, temperature, key)
    for i in range(gen_len):
        out.append(tok)
        if i == gen_len - 1:
            break
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        if key is not None:
            key = jax.random.fold_in(key, i)
        tok = _sample(logits, temperature, key)
    return jnp.concatenate(out, axis=1)


def _sample(logits, temperature, key):
    logits = logits[:, -1]
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature)[:, None] \
        .astype(jnp.int32)


def _grow_cache(model, cache, cur: int, max_len: int):
    """Pad full-attention KV caches from prefill length to max_len."""
    def grow(path, a):
        ks = jax.tree_util.keystr(path)
        if a.ndim == 5 and "mamba" not in ks and a.shape[2] == cur:
            pad = [(0, 0)] * 5
            pad[2] = (0, max_len - cur)
            return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map_with_path(grow, cache)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant-policy", default="",
                    help="json file: {site: [w_bits, a_bits]}")
    args = ap.parse_args()

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dot = None
    if args.quant_policy:
        policy = {k: tuple(v) for k, v in
                  json.load(open(args.quant_policy)).items()}
        dot = make_quant_dot(policy)
        print(f"serving with quantization policy over {len(policy)} sites")

    prompt = jnp.asarray(
        np.random.default_rng(0).integers(
            2, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompt, args.gen,
                   temperature=args.temperature,
                   key=jax.random.PRNGKey(1) if args.temperature > 0 else None)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {args.gen} tokens x batch {args.batch} "
          f"in {dt:.2f}s ({args.gen * args.batch / dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0, args.prompt_len:args.prompt_len + 16]))


if __name__ == "__main__":
    main()
