"""Deterministic synthetic LM data pipeline, host-sharded and restart-exact.

Design requirements from the fault-tolerance story (DESIGN.md §3):
  * stateless-deterministic: batch(step) is a pure function of (seed, step),
    so a restarted job resumes mid-epoch with byte-identical data — no
    shuffle-buffer state to checkpoint;
  * host-sharded: each host materializes only its slice of the global batch
    (process_index-based), like a tf.data service / Grain shard;
  * structured: Zipf unigrams + copy spans + induction patterns give models
    a real learnable signal (loss decreases), so examples/benchmarks can
    demonstrate end-to-end learning on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.4
    copy_frac: float = 0.5      # fraction of sequence that is copied prefix


def _host_slice(global_batch: int) -> tuple[int, int]:
    n = jax.process_count()
    idx = jax.process_index()
    per = global_batch // n
    return idx * per, per


def batch_at(dcfg: DataConfig, step: int, *, full: bool = False
             ) -> Dict[str, np.ndarray]:
    """The batch for `step` (pure function). full=True ignores host slicing."""
    start, per = (0, dcfg.global_batch) if full else _host_slice(
        dcfg.global_batch)
    rows = []
    for r in range(start, start + per):
        rng = np.random.default_rng(
            (dcfg.seed * 1_000_003 + step) * 65_521 + r)
        toks = np.clip(rng.zipf(dcfg.zipf_a, size=dcfg.seq_len), 2,
                       dcfg.vocab_size - 1)
        half = int(dcfg.seq_len * dcfg.copy_frac)
        if half > 1:
            toks[half:2 * half] = toks[:half]   # copy span (induction signal)
        rows.append(toks)
    tokens = np.stack(rows).astype(np.int32)
    return {"tokens": tokens, "labels": tokens}


def batches(dcfg: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(dcfg, step)
        step += 1


def batch_for_model(model, shape, dcfg: Optional[DataConfig], step: int
                    ) -> Dict[str, jnp.ndarray]:
    """Model-family-aware batch assembly (stub frontends get random embeds,
    deterministically from step)."""
    cfg = model.cfg
    dcfg = dcfg or DataConfig(cfg.vocab_size, shape.seq_len,
                              shape.global_batch)
    rng = np.random.default_rng(dcfg.seed * 7 + step)
    if cfg.is_encdec:
        Sd = max(shape.seq_len // cfg.dec_ratio, 2)
        dec = batch_at(dataclasses.replace(dcfg, seq_len=Sd), step)
        frames = rng.standard_normal(
            (shape.global_batch, shape.seq_len, cfg.d_model)).astype(np.float32)
        return {"frames": jnp.asarray(frames, jnp.bfloat16),
                "tokens": jnp.asarray(dec["tokens"]),
                "labels": jnp.asarray(dec["labels"])}
    if cfg.frontend == "vision_stub":
        Sp = int(shape.seq_len * cfg.patch_frac)
        St = shape.seq_len - Sp
        txt = batch_at(dataclasses.replace(dcfg, seq_len=St), step)
        patches = rng.standard_normal(
            (shape.global_batch, Sp, cfg.d_model)).astype(np.float32)
        return {"patches": jnp.asarray(patches, jnp.bfloat16),
                "tokens": jnp.asarray(txt["tokens"]),
                "labels": jnp.asarray(txt["labels"])}
    b = batch_at(dcfg, step)
    return {"tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"])}
