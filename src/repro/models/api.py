"""Unified model facade.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions —
the single entry point used by the trainer, the server, the dry-run, the NAS
supernet and the AMC/HAQ environments.

The ``dot`` hook threads HAQ quantization through every matmul: it receives
(activations, weights, site_name) and may dispatch to the Pallas quantized
kernel per the active bitwidth policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models import params as plib

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    defs: Any

    # -- parameters ---------------------------------------------------------
    def init(self, key) -> Any:
        return plib.init_params(self.defs, key)

    def abstract_params(self) -> Any:
        return plib.abstract_params(self.defs)

    def logical_specs(self) -> Any:
        return plib.logical_specs(self.defs)

    def param_count(self) -> int:
        return plib.param_count(self.defs)

    def param_bytes(self) -> int:
        return plib.param_bytes(self.defs)

    # -- compute ------------------------------------------------------------
    def forward(self, params, batch, *, want_cache=False, remat=False,
                ac=None, dot=None, unembed_mode="full",
                cache_layout="ring"):
        ac = ac or transformer._identity_ac
        if self.cfg.is_encdec:
            return encdec.forward(params, batch, self.cfg,
                                  want_cache=want_cache, remat=remat, ac=ac,
                                  dot=dot, unembed_mode=unembed_mode)
        return transformer.forward(params, batch, self.cfg,
                                   want_cache=want_cache, remat=remat, ac=ac,
                                   dot=dot, unembed_mode=unembed_mode,
                                   cache_layout=cache_layout)

    def loss(self, params, batch, *, remat=False, ac=None, dot=None):
        hidden, _, aux, fmask = self.forward(params, batch, want_cache=False,
                                             remat=remat, ac=ac, dot=dot,
                                             unembed_mode="none")
        labels = batch["labels"]
        if fmask is not None:  # vlm: loss only over the text segment
            S_txt = labels.shape[1]
            hidden = hidden[:, -S_txt:]
        ce = transformer.chunked_ce(params, hidden, labels, self.cfg, dot=dot)
        return ce + 0.01 * aux

    def prefill(self, params, batch, *, ac=None, dot=None,
                cache_layout="ring", unembed_mode="last"):
        logits, cache, _, _ = self.forward(params, batch, want_cache=True,
                                           ac=ac, dot=dot,
                                           unembed_mode=unembed_mode,
                                           cache_layout=cache_layout)
        return logits, cache

    def decode_step(self, params, cache, token, pos, *, ac=None, dot=None):
        step = encdec.decode_step if self.cfg.is_encdec \
            else transformer.decode_step
        ac = ac or transformer._identity_ac
        return step(params, cache, token, pos, self.cfg, ac=ac, dot=dot)

    def unembed(self, params, hidden, *, dot=None):
        """Project hidden states (B, S, D) to logits (decoder-only)."""
        return transformer.unembed(params, hidden, self.cfg, dot=dot)

    def decode_step_paged(self, params, pool, page_table, token, positions,
                          *, ac=None, dot=None, kernel="auto"):
        """Continuous-batching decode: per-sequence positions, KV walked
        page-by-page through the page table (see serving/engine). ``kernel``
        picks the paged-attention path: "auto" (Pallas on TPU, pure-JAX
        block walk elsewhere), "pallas", or "ref"."""
        ac = ac or transformer._identity_ac
        return transformer.decode_step_paged(params, pool, page_table, token,
                                             positions, self.cfg, ac=ac,
                                             dot=dot, kernel=kernel)

    def prefill_chunk_paged(self, params, pool, page_table, tokens,
                            positions, *, dot=None, kernel="auto"):
        """Chunked prefill: run one prompt chunk (tokens (B, Sq), first
        token of sequence b at absolute position ``positions[b]``) through
        the model, scattering its K/V into the paged pool and attending
        over the pool itself (resident prefix + chunk). Returns
        (hidden (B, Sq, D), new_pool); unembed the rows you need via
        ``unembed``. See transformer.prefill_chunk_paged."""
        return transformer.prefill_chunk_paged(params, pool, page_table,
                                               tokens, positions, self.cfg,
                                               dot=dot, kernel=kernel)

    # -- caches & inputs ----------------------------------------------------
    def cache_specs(self, batch: int, seq_len: int):
        fn = encdec.cache_specs if self.cfg.is_encdec \
            else transformer.cache_specs
        return fn(self.cfg, batch, seq_len)

    def init_cache(self, batch: int, seq_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(batch, seq_len))

    def pool_specs(self, num_pages: int, page_size: int, kv_bits=None):
        """``kv_bits`` selects the HAQ KV-quantized pool layout (int8/int4
        pages + per-page-slot scales) per sub-layer slot; None keeps the
        bf16 pool. See transformer.pool_specs / serving/kvquant."""
        return transformer.pool_specs(self.cfg, num_pages, page_size,
                                      kv_bits=kv_bits)

    def init_pool(self, num_pages: int, page_size: int, kv_bits=None):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.pool_specs(num_pages, page_size, kv_bits))

    def input_specs(self, shape) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one step's inputs (dry-run)."""
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        if shape.kind == "decode":
            return {
                "cache": self.cache_specs(B, S),
                "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
        batch: Dict[str, Any] = {}
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)
            Sd = max(S // cfg.dec_ratio, 2)
            batch["tokens"] = jax.ShapeDtypeStruct((B, Sd), jnp.int32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, Sd), jnp.int32)
        elif cfg.frontend == "vision_stub":
            Sp = int(S * cfg.patch_frac)
            batch["patches"] = jax.ShapeDtypeStruct((B, Sp, cfg.d_model),
                                                    jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((B, S - Sp), jnp.int32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S - Sp), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return batch

    def batch_logical_specs(self, shape) -> Dict[str, Any]:
        """Logical axes for the input batch (mirrors input_specs)."""
        if shape.kind == "decode":
            fn = encdec.cache_axes if self.cfg.is_encdec \
                else transformer.cache_axes
            return {"cache": fn(self.cfg),
                    "token": ("batch", "seq"),
                    "pos": ()}
        axes: Dict[str, Any] = {}
        cfg = self.cfg
        if cfg.is_encdec:
            axes["frames"] = ("batch", "seq", "embed_act")
            axes["tokens"] = ("batch", "seq")
            axes["labels"] = ("batch", "seq")
        elif cfg.frontend == "vision_stub":
            axes["patches"] = ("batch", "seq", "embed_act")
            axes["tokens"] = ("batch", "seq")
            axes["labels"] = ("batch", "seq")
        else:
            axes["tokens"] = ("batch", "seq")
            axes["labels"] = ("batch", "seq")
        return {k: v for k, v in axes.items()
                if k in self.input_specs(shape)}


def build_model(cfg) -> Model:
    defs = encdec.param_defs(cfg) if cfg.is_encdec \
        else transformer.param_defs(cfg)
    return Model(cfg=cfg, defs=defs)
