"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) in chunked JAX form.

Forward uses the SSD chunked algorithm: quadratic attention-like compute
inside length-Q chunks, linear state recurrence across chunks (lax.scan).
Decode is the O(1) recurrent update. All state math in fp32.

Block structure (mamba_block_*):
  in_proj -> [z | xs | B | C | dt] -> causal depthwise conv(xs,B,C) -> SiLU
  -> SSD -> gated RMSNorm (y * silu(z)) -> out_proj
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.params import PDef

F32 = jnp.float32


def mamba_defs(cfg) -> dict:
    d, s = cfg.d_model, cfg.ssm
    di = cfg.d_inner
    H = cfg.ssm_heads
    G, N = s.n_groups, s.d_state
    d_conv = di + 2 * G * N
    return {
        "in_proj": PDef((d, 2 * di + 2 * G * N + H), ("embed", "ssm_inner"),
                        "scaled"),
        "conv_w": PDef((s.conv_width, d_conv), ("conv", "ssm_inner"),
                       "scaled", scale=0.5),
        "conv_b": PDef((d_conv,), ("ssm_inner",), "zeros"),
        "a_log": PDef((H,), ("null",), "zeros", dtype=jnp.float32),
        "dt_bias": PDef((H,), ("null",), "zeros", dtype=jnp.float32),
        "d_skip": PDef((H,), ("null",), "ones", dtype=jnp.float32),
        "norm": PDef((di,), ("ssm_inner",), "zeros", dtype=jnp.float32),
        "out_proj": PDef((di, d), ("ssm_inner", "embed"), "scaled"),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner
    G, N = cfg.ssm.n_groups, cfg.ssm.d_state
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, xs, Bm, Cm, dt


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=F32)
    for i in range(W):  # W is 4; unrolled taps beat a conv op on TPU VPU
        out = out + xp[:, i:i + x.shape[1]].astype(F32) * w[i].astype(F32)
    return (out + b.astype(F32)).astype(x.dtype)


def ssd_chunked(xh, dt, a_log, Bm, Cm, chunk: int):
    """SSD scan. xh (B,S,H,P), dt (B,S,H) fp32 post-softplus, Bm/Cm (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:  # pad with dt=0/x=0 tokens: state-neutral (decay 1, contrib 0)
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    hg = H // G
    A = -jnp.exp(a_log.astype(F32))                       # (H,) negative

    xc = xh.reshape(B, nc, Q, H, P).astype(F32)
    dtc = dt.reshape(B, nc, Q, H)
    Bc = Bm.reshape(B, nc, Q, G, N).astype(F32)
    Cc = Cm.reshape(B, nc, Q, G, N).astype(F32)

    dA = dtc * A                                          # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                          # within-chunk
    # intra-chunk (masked "attention"): L[i,j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores_gij = C_i . B_j  per group -> expand to heads
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)         # (B,nc,Q,Q,G)
    CB = jnp.repeat(CB, hg, axis=-1)                      # (B,nc,Q,Q,H)
    W = CB * L * dtc[:, :, None, :, :]                    # weight on x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc)

    # chunk summary states: sum_j exp(cum_Q - cum_j) dt_j B_j x_j
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, hg, axis=3).reshape(B, nc, Q, H, N)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay_tail * dtc, Bh, xc)         # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def step(carry, inp):
        st, (s_c, dec) = carry, inp
        new = st * dec[:, :, None, None] + s_c
        return new, st                                    # emit state BEFORE chunk

    init = jnp.zeros((B, H, P, N), F32)
    xs_scan = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final, prevs = jax.lax.scan(step, init, xs_scan)
    prev_states = jnp.moveaxis(prevs, 0, 1)               # (B,nc,H,P,N)

    # inter-chunk: y_i += C_i . (exp(cum_i) * prev_state)
    Ch = jnp.repeat(Cc, hg, axis=3).reshape(B, nc, Q, H, N)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Ch, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    if S != S_orig:
        y = jax.lax.slice_in_dim(y, 0, S_orig, axis=1)
    return y, final


def mamba_block_fwd(p, x, cfg, *, dot=None) -> Tuple[jax.Array, dict]:
    """x (B,S,D) -> (y (B,S,D), cache {conv_state, ssm_state})."""
    B, S, D = x.shape
    s = cfg.ssm
    di, H, P = cfg.d_inner, cfg.ssm_heads, s.head_dim
    G, N = s.n_groups, s.d_state
    dot = dot or (lambda a, w, name: jnp.einsum(
        "bsd,de->bse", a, w))
    zxbcdt = dot(x, p["in_proj"], "ssm_in")
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    xh = xs.reshape(B, S, H, P)
    y, final = ssd_chunked(xh, dtf, p["a_log"], Bm.reshape(B, S, G, N),
                           Cm.reshape(B, S, G, N), s.chunk)
    y = y + xh.astype(F32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dot(y, p["out_proj"], "ssm_out")
    tail = jax.lax.slice_in_dim(conv_in, max(S - (s.conv_width - 1), 0), S,
                                axis=1)
    cache = {"conv": tail, "state": final.astype(F32)}
    return out, cache


def mamba_block_decode(p, x, cache, cfg, *, dot=None):
    """One-token decode. x (B,1,D); cache {conv (B,W-1,C), state (B,H,P,N)}."""
    B = x.shape[0]
    s = cfg.ssm
    di, H, P = cfg.d_inner, cfg.ssm_heads, s.head_dim
    G, N = s.n_groups, s.d_state
    dot = dot or (lambda a, w, name: jnp.einsum(
        "bsd,de->bse", a, w))
    zxbcdt = dot(x, p["in_proj"], "ssm_in")
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)      # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(F32),
                          p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])  # (B,1,H)
    A = -jnp.exp(p["a_log"].astype(F32))
    dA = jnp.exp(dtf[:, 0, :] * A)                        # (B,H)
    xh = xs.reshape(B, H, P).astype(F32)
    Bh = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    state = cache["state"] * dA[:, :, None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dtf[:, 0], Bh.astype(F32), xh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(F32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dot(y, p["out_proj"], "ssm_out")
    new_cache = {"conv": window[:, 1:], "state": state}
    return out, new_cache


def mamba_cache_spec(cfg, batch: int):
    """ShapeDtypeStructs for one layer's decode cache."""
    s = cfg.ssm
    d_conv = cfg.d_inner + 2 * s.n_groups * s.d_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, d_conv),
                                     jnp.bfloat16),
        "state": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32),
    }
