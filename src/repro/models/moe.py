"""Mixture-of-Experts FFN: top-k routing, sort-based fixed-capacity dispatch,
batched-einsum expert compute (GShard-style, TPU/MXU-friendly).

The dispatch avoids the (T, E, C) one-hot tensor: routed pairs are sorted by
expert id and scattered into an (E, C, D) buffer, experts run as one batched
einsum (shardable over the "experts" logical axis), and outputs scatter-add
back per token weighted by the gate. Capacity overflow drops tokens (standard
GShard semantics); the residual path keeps dropped tokens intact.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.params import PDef

F32 = jnp.float32


def moe_defs(d_model: int, moe) -> dict:
    E, f = moe.num_experts, moe.d_ff_expert
    return {
        "router": PDef((d_model, E), ("embed", "experts"), "scaled",
                       dtype=jnp.float32),
        "w_in": PDef((E, d_model, f), ("experts", "embed", "expert_ff"),
                     "scaled"),
        "w_gate": PDef((E, d_model, f), ("experts", "embed", "expert_ff"),
                       "scaled"),
        "w_out": PDef((E, f, d_model), ("experts", "expert_ff", "embed"),
                      "scaled"),
    }


def capacity(tokens: int, moe) -> int:
    c = math.ceil(tokens * moe.experts_per_token * moe.capacity_factor
                  / moe.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 (VPU sublane)


def moe_apply(p, x: jax.Array, moe, activation: str = "swiglu",
              *, dot=None, ac=None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar). `ac` hints the
    dispatch-buffer sharding (see distributed.sharding.make_ac)."""
    B, S, D = x.shape
    T = B * S
    E, k = moe.num_experts, moe.experts_per_token
    C = capacity(T, moe)
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=F32), axis=0)
    aux = E * jnp.sum(me * ce)

    e_flat = idx.reshape(T * k)
    g_flat = gates.reshape(T * k).astype(x.dtype)
    order = jnp.argsort(e_flat)                              # stable
    e_sorted = e_flat[order]
    tok_sorted = order // k
    counts = jnp.bincount(e_flat, length=E)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - seg_start[e_sorted]
    keep = pos < C
    dest = jnp.where(keep, e_sorted * C + pos, E * C)        # OOB row drops

    x_sorted = xf[tok_sorted]
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[dest].set(x_sorted)
    buf = buf[:-1].reshape(E, C, D)
    if ac is not None:
        buf = ac(buf, "moe_buf")

    dot_e = dot or (lambda a, w, name: jnp.einsum(
        "ecd,edf->ecf", a, w))
    h = dot_e(buf, p["w_in"], "moe_in")
    g = dot_e(buf, p["w_gate"], "moe_gate")
    if activation == "swiglu":
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(g, approximate=True) * h
    dot_o = dot or (lambda a, w, name: jnp.einsum(
        "ecf,efd->ecd", a, w))
    out_buf = dot_o(h, p["w_out"], "moe_out")
    if ac is not None:
        out_buf = ac(out_buf, "moe_buf")
    out_buf = out_buf.reshape(E * C, D)

    safe_dest = jnp.minimum(dest, E * C - 1)
    y_sorted = out_buf[safe_dest] * (keep & (dest < E * C))[:, None]
    contrib = y_sorted * g_flat[order][:, None]
    y = jnp.zeros((T, D), x.dtype).at[tok_sorted].add(contrib)
    return y.reshape(B, S, D), aux
