"""Shared layer library: norms, RoPE, FFN variants, softcap.

Everything is a pure function of (params, x); computation runs in bf16 with
fp32 accumulations where numerically required (norm statistics, attention
logits, router logits).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import PDef

F32 = jnp.float32


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions.astype(F32)[..., None] * freqs        # (..., seq, hd/2)
    angles = angles[..., None, :]                            # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- FFN ----
def ffn_defs(d_model: int, d_ff: int, activation: str, ff_axis: str = "d_ff"):
    gated = activation in ("swiglu", "geglu")
    defs = {
        "w_in": PDef((d_model, d_ff), ("embed", ff_axis), "scaled"),
        "w_out": PDef((d_ff, d_model), (ff_axis, "embed"), "scaled"),
    }
    if gated:
        defs["w_gate"] = PDef((d_model, d_ff), ("embed", ff_axis), "scaled")
    return defs


def _act(h: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(h, approximate=True)
    if kind == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def ffn_apply(p, x: jax.Array, activation: str, *, dot=None) -> jax.Array:
    """dot: optional (x, w, name) -> y override (HAQ quantized path)."""
    dot = dot or (lambda a, w, name: jnp.einsum(
        "...d,df->...f", a, w))
    h = dot(x, p["w_in"], "ffn_in")
    if "w_gate" in p:
        g = dot(x, p["w_gate"], "ffn_gate")
        h = _act(g, activation) * h
    else:
        h = _act(h, activation)
    return dot(h, p["w_out"], "ffn_out")


def embed_defs(vocab: int, d_model: int):
    return PDef((vocab, d_model), ("vocab", "embed"), "normal")


def norm_def(d_model: int):
    return PDef((d_model,), ("embed",), "zeros", dtype=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., V) fp32-accumulated."""
    logits = logits.astype(F32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(F32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
