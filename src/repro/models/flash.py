"""Blockwise (flash-style) attention in pure XLA with a custom VJP.

Used for every sequence long enough that materializing (S, T) score matrices
is infeasible (threshold FLASH_MIN). Forward is the classic online-softmax
over KV blocks; backward recomputes scores blockwise (two double-scans: one
for dq, one for dk/dv), so live memory stays O(S·d) instead of O(S²).

GQA is handled by repeating KV blocks to the full head count *inside* a
block — the (K, G) reshape would break head sharding whenever TP > K (e.g.
gemma2's 4 KV heads on a 16-way model axis); repeated blocks keep the heads
axis cleanly sharded and the cache stays K-headed.

This is the XLA twin of the Pallas kernel in repro/kernels/flash_attention.py
(same blocking, same math); the Pallas version is the TPU target, this one is
what the multi-pod dry-run lowers. Masked blocks are still computed (2×
causal waste) — see EXPERIMENTS.md §Perf for the measured impact and the
kernel-side fix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG = -1e30
FLASH_MIN = 2048          # use flash above this q-length
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512


def _block_mask(i, j, Qc: int, Kc: int, kind: str, window: int):
    """(Qc, Kc) bool mask for q-block i vs kv-block j."""
    qpos = i * Qc + jnp.arange(Qc)[:, None]
    kpos = j * Kc + jnp.arange(Kc)[None, :]
    if kind == "bidir":
        return jnp.ones((Qc, Kc), bool)
    m = kpos <= qpos
    if kind == "local":
        m &= kpos > qpos - window
    return m


def _scores(qb, kb, scale: float, cap: float):
    """qb (B,Qc,H,hd) kb (B,Kc,H,hd) -> (B,H,Qc,Kc) fp32 (softcapped)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", qb.astype(F32), kb.astype(F32)) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    return s


def _rep(k, G):
    return jnp.repeat(k, G, axis=2) if G > 1 else k


def _fwd_impl(q, k, v, kind: str, window: int, cap: float,
              block_q: int, block_kv: int):
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    Qc = min(block_q, S)
    Kc = min(block_kv, T)
    assert S % Qc == 0 and T % Kc == 0, (S, T, Qc, Kc)
    nq, nk = S // Qc, T // Kc

    qb = jnp.moveaxis(q.reshape(B, nq, Qc, H, hd), 1, 0)

    # NOTE: block indices i/j are threaded through the scan CARRY (not iota
    # xs): XLA's while-loop invariant code motion otherwise precomputes the
    # (i, j)-dependent masks for every iteration as one giant stacked pred
    # tensor (observed 2 GiB/device on the CPU dry-run backend).
    def q_body(i, qi):
        def kv_body(carry, _):
            m, l, acc, j = carry
            kj = _rep(jax.lax.dynamic_slice_in_dim(k, j * Kc, Kc, 1), G)
            vj = _rep(jax.lax.dynamic_slice_in_dim(v, j * Kc, Kc, 1), G)
            s = _scores(qi, kj, scale, cap)                  # (B,H,Qc,Kc)
            s = jnp.where(_block_mask(i, j, Qc, Kc, kind, window)
                          [None, None], s, NEG)
            new_m = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - new_m)
            p = jnp.exp(s - new_m[..., None])
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vj.astype(F32))
            acc = acc * corr[..., None] + pv
            return (new_m, l, acc, j + 1), None

        m0 = jnp.full((B, H, Qc), NEG, F32)
        l0 = jnp.zeros((B, H, Qc), F32)
        a0 = jnp.zeros((B, H, Qc, hd), F32)
        (m, l, acc, _), _ = jax.lax.scan(
            kv_body, (m0, l0, a0, jnp.zeros((), jnp.int32)), None, length=nk)
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,H,Qc,hd)
        lse_i = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,H,Qc)
        return i + 1, (out_i, lse_i)

    _, (ob, lse_b) = jax.lax.scan(q_body, jnp.zeros((), jnp.int32), qb)
    out = jnp.moveaxis(ob, 0, 2).reshape(B, H, S, hd)        # (B,H,S,hd)
    out = jnp.moveaxis(out, 1, 2).astype(q.dtype)            # (B,S,H,hd)
    lse = jnp.moveaxis(lse_b, 0, 2).reshape(B, H, S)
    return out, lse


@functools.lru_cache(maxsize=None)
def _make_flash(kind: str, window: int, cap: float, block_q: int,
                block_kv: int):
    """custom_vjp closure over the static attention config. Static values are
    captured by closure (not nondiff_argnums): with nondiff_argnums, scan
    partial-eval was observed to stage the fwd impl's internal residuals
    (stacked block masks) instead of treating the call as opaque."""

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = _fwd_impl(q, k, v, kind, window, cap, block_q, block_kv)
        return out

    def fwd_rule(q, k, v):
        out, lse = _fwd_impl(q, k, v, kind, window, cap, block_q, block_kv)
        return out, (q, k, v, out, lse)

    def bwd_rule(res, dout):
        return _bwd_impl(kind, window, cap, block_q, block_kv, res, dout)

    attn.defvjp(fwd_rule, bwd_rule)
    return attn


def flash_attention(q, k, v, kind: str = "global", window: int = 0,
                    cap: float = 0.0, block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV):
    return _make_flash(kind, int(window), float(cap), int(block_q),
                       int(block_kv))(q, k, v)


def _bwd_impl(kind, window, cap, block_q, block_kv, res, dout):
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    Qc = min(block_q, S)
    Kc = min(block_kv, T)
    nq, nk = S // Qc, T // Kc

    doutf = dout.astype(F32)
    delta = jnp.einsum("bshd,bshd->bhs", doutf, out.astype(F32))  # (B,H,S)

    qb = jnp.moveaxis(q.reshape(B, nq, Qc, H, hd), 1, 0)
    dob = jnp.moveaxis(doutf.reshape(B, nq, Qc, H, hd), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, H, nq, Qc), 2, 0)          # (nq,B,H,Qc)
    deltab = jnp.moveaxis(delta.reshape(B, H, nq, Qc), 2, 0)

    def _p_and_ds(qi, kj, lse_i, delta_i, do_i, vj, i, j):
        """Recompute P_ij and dS_ij (pre-scale, pre-softcap-chain)."""
        raw = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(F32),
                         kj.astype(F32)) * scale
        s = cap * jnp.tanh(raw / cap) if cap else raw
        s = jnp.where(_block_mask(i, j, Qc, Kc, kind, window)[None, None],
                      s, NEG)
        p = jnp.exp(s - lse_i[..., None])                         # normalized
        dp = jnp.einsum("bqhd,bkhd->bhqk", do_i, vj.astype(F32))
        ds = p * (dp - delta_i[..., None])
        if cap:
            ds = ds * (1.0 - jnp.square(jnp.tanh(raw / cap)))
        return p, ds

    # ---- pass 1: dq (outer q blocks, inner kv blocks); indices in carries
    def dq_body(i, xs):
        qi, do_i, lse_i, delta_i = xs

        def inner(carry, _):
            dq_acc, j = carry
            kj = _rep(jax.lax.dynamic_slice_in_dim(k, j * Kc, Kc, 1), G)
            vj = _rep(jax.lax.dynamic_slice_in_dim(v, j * Kc, Kc, 1), G)
            _, ds = _p_and_ds(qi, kj, lse_i, delta_i, do_i, vj, i, j)
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                         kj.astype(F32)) * scale
            return (dq_acc, j + 1), None

        dq0 = jnp.zeros((B, Qc, H, hd), F32)
        (dq_i, _), _ = jax.lax.scan(inner, (dq0, jnp.zeros((), jnp.int32)),
                                    None, length=nk)
        return i + 1, dq_i

    _, dqb = jax.lax.scan(dq_body, jnp.zeros((), jnp.int32),
                          (qb, dob, lseb, deltab))
    dq = jnp.moveaxis(dqb, 0, 1).reshape(B, S, H, hd).astype(q.dtype)

    # ---- pass 2: dk, dv (outer kv blocks, inner q blocks)
    def dkv_body(j, _):
        kj = _rep(jax.lax.dynamic_slice_in_dim(k, j * Kc, Kc, 1), G)
        vj = _rep(jax.lax.dynamic_slice_in_dim(v, j * Kc, Kc, 1), G)

        def inner(carry, xs):
            dk_acc, dv_acc, i = carry
            qi, do_i, lse_i, delta_i = xs
            p, ds = _p_and_ds(qi, kj, lse_i, delta_i, do_i, vj, i, j)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, do_i)
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds,
                                         qi.astype(F32)) * scale
            return (dk_acc, dv_acc, i + 1), None

        z = jnp.zeros((B, Kc, H, hd), F32)
        (dk_j, dv_j, _), _ = jax.lax.scan(
            inner, (z, z, jnp.zeros((), jnp.int32)),
            (qb, dob, lseb, deltab))
        # fold repeated heads back to K kv-heads
        dk_j = dk_j.reshape(B, Kc, K, G, hd).sum(3)
        dv_j = dv_j.reshape(B, Kc, K, G, hd).sum(3)
        return j + 1, (dk_j, dv_j)

    _, (dkb, dvb) = jax.lax.scan(dkv_body, jnp.zeros((), jnp.int32),
                                 None, length=nk)
    dk = jnp.moveaxis(dkb, 0, 1).reshape(B, T, K, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvb, 0, 1).reshape(B, T, K, hd).astype(v.dtype)
    return dq, dk, dv


