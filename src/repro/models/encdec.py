"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: the input batch carries
precomputed frame embeddings ``frames (B, S_enc, d_model)``. Positions are
sinusoidal (no RoPE, cfg.rope_theta == 0). num_layers applies to both stacks;
decoder length = seq_len // cfg.dec_ratio.

Decode caches: per decoder layer a growing self-attn KV cache plus a static
cross-attn KV computed once from the encoder output at prefill.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import ffn_apply, ffn_defs, norm_def, rms_norm
from repro.models.params import PDef, stacked
from repro.models.transformer import embed_tokens, unembed, _identity_ac

F32 = jnp.float32


def sinusoidal(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=F32)[:, None]
    dim = jnp.arange(d // 2, dtype=F32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_defs(cfg):
    d = cfg.d_model
    return {
        "ln1": norm_def(d),
        "attn": attn.attn_defs(d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim),
        "ln2": norm_def(d),
        "ffn": ffn_defs(d, cfg.d_ff, cfg.activation),
    }


def _dec_layer_defs(cfg):
    d = cfg.d_model
    return {
        **_enc_layer_defs(cfg),
        "ln_x": norm_def(d),
        "xattn": attn.attn_defs(d, cfg.num_heads, cfg.num_kv_heads,
                                cfg.resolved_head_dim),
    }


def param_defs(cfg) -> dict:
    d = cfg.d_model
    return {
        "embed": PDef((cfg.padded_vocab, d), ("vocab", "embed"), "normal"),
        "enc": stacked({"l": _enc_layer_defs(cfg)}, cfg.num_layers)["l"],
        "dec": stacked({"l": _dec_layer_defs(cfg)}, cfg.num_layers)["l"],
        "enc_norm": norm_def(d),
        "final_norm": norm_def(d),
        "lm_head": PDef((d, cfg.padded_vocab), ("embed", "vocab"), "scaled"),
    }


def encode(params, frames, cfg, *, remat=False, ac=_identity_ac, dot=None):
    B, S, D = frames.shape
    x = frames.astype(jnp.bfloat16) + sinusoidal(S, D).astype(jnp.bfloat16)
    x = ac(x, "resid")

    def body(h, p):
        a, _ = attn.attention_fwd(p["attn"], rms_norm(h, p["ln1"],
                                                      cfg.norm_eps),
                                  "bidir", cfg, None, dot=dot)
        h = ac(h + a, "resid")
        f = ffn_apply(p["ffn"], rms_norm(h, p["ln2"], cfg.norm_eps),
                      cfg.activation, dot=dot)
        return ac(h + f, "resid"), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_fwd(params, mem, tokens, cfg, *, want_cache: bool, remat=False,
               ac=_identity_ac, dot=None, unembed_mode: str = "full"):
    """Teacher-forced decoder pass. Returns (logits, caches|None)."""
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    x = x + sinusoidal(S, cfg.d_model).astype(x.dtype)
    x = ac(x, "resid")

    def body(h, p):
        a, sc = attn.attention_fwd(p["attn"], rms_norm(h, p["ln1"],
                                                       cfg.norm_eps),
                                   "global", cfg, None, dot=dot)
        h = ac(h + a, "resid")
        mk, mv = attn.cross_kv(p["xattn"], mem, dot=dot)
        c = attn.cross_attention(p["xattn"], rms_norm(h, p["ln_x"],
                                                      cfg.norm_eps),
                                 mk, mv, cfg, dot=dot)
        h = ac(h + c, "resid")
        f = ffn_apply(p["ffn"], rms_norm(h, p["ln2"], cfg.norm_eps),
                      cfg.activation, dot=dot)
        h = ac(h + f, "resid")
        out = {"k": sc["k"], "v": sc["v"], "mk": mk, "mv": mv} \
            if want_cache else None
        return h, out

    body = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(body, x, params["dec"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if unembed_mode == "none":
        return x, caches
    if unembed_mode == "last":
        x = x[:, -1:]
    return unembed(params, x, cfg, dot=dot), caches


def forward(params, batch, cfg, *, want_cache: bool, remat=False,
            ac=_identity_ac, dot=None, unembed_mode: str = "full"):
    """batch: {frames (B,S,D), tokens (B,S_dec)}. Matches transformer.forward
    signature: returns (logits, caches, aux, loss_mask)."""
    mem = encode(params, batch["frames"], cfg, remat=remat, ac=ac, dot=dot)
    logits, caches = decode_fwd(params, mem, batch["tokens"], cfg,
                                want_cache=want_cache, remat=remat, ac=ac,
                                dot=dot, unembed_mode=unembed_mode)
    return logits, caches, jnp.zeros((), F32), None


def decode_step(params, cache, token, pos, cfg, *, ac=_identity_ac, dot=None):
    """One decoder token. cache: {k,v (L,B,Sd,K,hd), mk,mv (L,B,Se,K,hd)}."""
    x = embed_tokens(params, token, cfg)
    d = cfg.d_model
    pe = sinusoidal_at(pos, d).astype(x.dtype)
    x = x + pe[None, None, :]

    def body(h, xs):
        p, c = xs
        a, ck, cv = attn.attention_decode(
            p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps), c["k"], c["v"],
            pos, "global", cfg, dot=dot)
        h = h + a
        cx = attn.cross_attention(p["xattn"], rms_norm(h, p["ln_x"],
                                                       cfg.norm_eps),
                                  c["mk"], c["mv"], cfg, dot=dot)
        h = h + cx
        f = ffn_apply(p["ffn"], rms_norm(h, p["ln2"], cfg.norm_eps),
                      cfg.activation, dot=dot)
        return h + f, {"k": ck, "v": cv, "mk": c["mk"], "mv": c["mv"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg, dot=dot), new_cache


def sinusoidal_at(pos, d: int) -> jax.Array:
    dim = jnp.arange(d // 2, dtype=F32)
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos.astype(F32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cache_specs(cfg, batch: int, seq_len: int):
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    L = cfg.num_layers
    S_dec = max(seq_len // cfg.dec_ratio, 1)

    def sd(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.bfloat16)

    return {
        "k": sd(L, batch, S_dec, K, hd),
        "v": sd(L, batch, S_dec, K, hd),
        "mk": sd(L, batch, seq_len, K, hd),
        "mv": sd(L, batch, seq_len, K, hd),
    }


def cache_axes(cfg):
    ax = ("layer", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "mk": ax, "mv": ax}
