"""GQA attention: full / sliding-window (local) / cross, train + prefill +
decode paths, with full-cache and ring-buffer (local) KV caches.

Layout conventions:
  activations x          (B, S, D)
  q                      (B, S, H, hd)
  k, v                   (B, S, K, hd)     H = K * G (GQA groups)
  full KV cache          (B, S_max, K, hd)
  ring KV cache (local)  (B, W, K, hd)     slot = position % W
Attention logits are computed in fp32; RoPE is applied at cache-write time
(absolute positions), which keeps ring-buffer decode exact.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import flash as flash_lib
from repro.models.layers import apply_rope, softcap
from repro.models.params import PDef

F32 = jnp.float32
NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaNs for fully-masked rows


def attn_defs(d_model: int, n_heads: int, n_kv: int, head_dim: int):
    return {
        "wq": PDef((d_model, n_heads, head_dim),
                   ("embed", "heads", "head_dim"), "scaled"),
        "wk": PDef((d_model, n_kv, head_dim),
                   ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": PDef((d_model, n_kv, head_dim),
                   ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": PDef((n_heads, head_dim, d_model),
                   ("heads", "head_dim", "embed"), "scaled"),
    }


def qkv(p, x, theta: float, positions, *, dot=None):
    """Project and rope. positions: (B, S) absolute positions (or None)."""
    if dot is None:
        dot = lambda a, w, name: jnp.einsum(
            "bsd,dnh->bsnh", a, w)
    q = dot(x, p["wq"], "attn_q")
    k = dot(x, p["wk"], "attn_k")
    v = dot(x, p["wv"], "attn_v")
    if theta > 0 and positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def _attend(q, k, v, mask, cap: float, *, ac=None):
    """Dense attention (short sequences / decode). KV repeated to H heads so
    the heads axis shards cleanly even when TP > n_kv (see flash.py).
    mask broadcastable to (B,H,S,T). Returns (B,S,H,hd).

    `ac` (decode path): sequence-parallel hints — q replicated over the model
    axis, kv/scores sharded over cache-seq; softmax and the PV contraction
    then partition over the cache with only tiny combine collectives."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if ac is not None:
        q = ac(q, "decode_q")
        k = ac(k, "decode_kv")
        v = ac(v, "decode_kv")
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32))
    if ac is not None:
        s = ac(s, "decode_scores")
    s = softcap(s * (hd ** -0.5), cap)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(F32))
    return o.astype(q.dtype)


def causal_mask(S: int, T: int, q_offset=0):
    i = jnp.arange(S)[:, None] + q_offset
    j = jnp.arange(T)[None, :]
    return (j <= i)[None, None]


def local_mask(S: int, T: int, window: int, q_offset=0):
    i = jnp.arange(S)[:, None] + q_offset
    j = jnp.arange(T)[None, :]
    return ((j <= i) & (j > i - window))[None, None]


def attention_fwd(p, x, kind: str, cfg, positions, *, dot=None,
                  segment_ids=None, ring: bool = True
                  ) -> Tuple[jax.Array, dict]:
    """Training/prefill attention. Returns (out (B,S,D), cache_entry).

    kind: "global" | "local" | "bidir".
    cache_entry holds roped k/v ready for decode (ring layout for local;
    ``ring=False`` keeps local caches in chronological full layout so the
    paged serving engine can copy them into its page pool).
    """
    B, S, D = x.shape
    q, k, v = qkv(p, x, cfg.rope_theta, positions, dot=dot)
    W = cfg.window_size
    if S >= flash_lib.FLASH_MIN and segment_ids is None:
        o = flash_lib.flash_attention(q, k, v, kind, W, cfg.attn_softcap)
    else:
        if kind == "local":
            mask = local_mask(S, S, W)
        elif kind == "bidir":
            mask = jnp.ones((1, 1, S, S), bool)
        else:
            mask = causal_mask(S, S)
        if segment_ids is not None:  # block packed-sequence cross-talk
            seg = (segment_ids[:, :, None] == segment_ids[:, None, :])
            mask = mask & seg[:, None]
        o = _attend(q, k, v, mask, cfg.attn_softcap)
    dot_o = dot or (lambda a, w, name: jnp.einsum(
        "bsnh,nhd->bsd", a, w))
    out = dot_o(o, p["wo"], "attn_o")
    cache = {"k": k, "v": v}
    if ring and kind == "local" and S >= W:
        cache = {"k": _last_window_ring(k, W), "v": _last_window_ring(v, W)}
    return out, cache


def _last_window_ring(k: jax.Array, W: int) -> jax.Array:
    """Rearrange the last W cached positions into ring layout (slot=pos%W)."""
    S = k.shape[1]
    last = jax.lax.slice_in_dim(k, S - W, S, axis=1)  # positions S-W..S-1
    # slot s holds position S-W + ((s - (S-W)) % W)
    inv = np.array([(s - (S - W)) % W for s in range(W)])
    return last[:, inv]


def _cache_write(cache: jax.Array, new: jax.Array, idx) -> jax.Array:
    """Write `new` (B,1,K,hd) at seq position idx. Uses a scatter (.at.set)
    rather than dynamic_update_slice: the SPMD partitioner keeps a scatter
    with replicated scalar indices LOCAL on a seq-sharded cache, whereas a
    dynamic-update-slice at a traced offset falls back to all-gathering the
    whole cache shard per layer (observed 87 GB/device/token on the
    decode_32k dry-run — see EXPERIMENTS.md §Perf iteration D2)."""
    return cache.at[:, idx].set(new[:, 0], mode="promise_in_bounds")


def attention_decode(p, x, cache_k, cache_v, pos, kind: str, cfg, *,
                     dot=None, ac=None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x (B,1,D); pos scalar int32 (current position).

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = qkv(p, x, cfg.rope_theta, positions, dot=dot)
    T = cache_k.shape[1]
    if kind == "local" and T == cfg.window_size:
        slot = jnp.mod(pos, T)
        cache_k = _cache_write(cache_k, k_new, slot)
        cache_v = _cache_write(cache_v, v_new, slot)
        # absolute position held by each slot (after this write)
        s = jnp.arange(T)
        abs_pos = pos - jnp.mod(pos - s, T)
        mask = (abs_pos >= 0)[None, None, None, :]
    else:
        cache_k = _cache_write(cache_k, k_new, pos)
        cache_v = _cache_write(cache_v, v_new, pos)
        j = jnp.arange(T)
        valid = j <= pos
        if kind == "local":
            valid &= j > pos - cfg.window_size
        mask = valid[None, None, None, :]
    o = _attend(q, cache_k, cache_v, mask, cfg.attn_softcap, ac=ac)
    dot_o = dot or (lambda a, w, name: jnp.einsum(
        "bsnh,nhd->bsd", a, w))
    out = dot_o(o, p["wo"], "attn_o")
    return out, cache_k, cache_v


def attention_decode_paged(p, x, pool_k, pool_v, page_table, positions,
                           kind: str, cfg, *, dot=None, ac=None,
                           kernel: str = "auto"):
    """Slot-indexed one-token decode against a paged KV pool.

    x           (B, 1, D)   one new token's activations per sequence
    pool_k/v    (P, page, K, hd)  this layer's physical page pool
    page_table  (B, n_pages) int32 physical page ids per logical block;
                unused tail entries must point at the scratch page 0
    positions   (B,) int32  absolute position of the incoming token (== the
                number of tokens already cached for that sequence)
    kernel      "auto" | "pallas" | "ref" — kernels/ops.py::paged_attention
                dispatch: the Pallas page-walk kernel on TPU, the pure-JAX
                block walk elsewhere. Neither path materializes the dense
                chronological (B, n_pages*page, K, hd) KV view, and local
                layers walk only the window's pages instead of masking a
                full-length gather.

    The new k/v are scattered into page ``page_table[b, pos // page]`` at
    slot ``pos % page``; attention then walks the sequence's pages in
    chronological order, masking columns beyond ``positions[b]`` (and
    outside the sliding window for local layers). Because RoPE is applied
    at cache-write time with absolute positions, the page walk matches a
    dense chronological cache to fp32-accumulation precision.

    Quantized pools (serving/kvquant): ``pool_k``/``pool_v`` may instead be
    ``{"q": int8 pages, "scale": fp32 (P, page, K)}`` dicts — the stored
    bitwidth (int8, or int4 packed along head_dim) is inferred from the
    stored minor-dim size. The incoming token's k/v are quantized on write
    (per-token per-head symmetric scales, the same mapping the engine's
    prefill writer uses), and attention runs the fused-dequant walk — no
    dense fp KV view is materialized on either path.

    ``ac`` (sequence-parallel decode hints) applies to the dense decode
    path only; the paged walk ignores it. Sharded paged decode instead
    rides shard_map (serving/engine/sharded.py): the pool arrives as a
    local kv-head slice and this function runs unchanged per shard — the
    walk is embarrassingly parallel over heads, and the ``dot`` hook
    (sharded.tp_dot) all-gathers the per-head outputs before the
    out-projection so the contraction keeps its 1-device reduction order.

    Returns (out (B,1,D), pool_k, pool_v).
    """
    quantized = isinstance(pool_k, dict)
    page = (pool_k["q"] if quantized else pool_k).shape[1]
    q, k_new, v_new = qkv(p, x, cfg.rope_theta, positions[:, None], dot=dot)
    pids = jnp.take_along_axis(page_table, (positions // page)[:, None],
                               axis=1)[:, 0]
    slots = positions % page
    window = cfg.window_size if kind == "local" else 0
    if quantized:
        hd = q.shape[-1]
        bits = kref.kv_bits_of(pool_k["q"], hd)

        def write(pool, new):                        # new: (B, K, hd)
            qv, sc = kref.quantize_kv(new, bits)
            return {"q": pool["q"].at[pids, slots].set(
                        qv, mode="promise_in_bounds"),
                    "scale": pool["scale"].at[pids, slots].set(
                        sc, mode="promise_in_bounds")}

        pool_k = write(pool_k, k_new[:, 0])
        pool_v = write(pool_v, v_new[:, 0])
        o = kops.paged_attention_quant(
            q[:, 0], pool_k["q"], pool_k["scale"], pool_v["q"],
            pool_v["scale"], page_table, positions, window=window,
            cap=cfg.attn_softcap, mode=kernel)[:, None]
    else:
        pool_k = pool_k.at[pids, slots].set(k_new[:, 0],
                                            mode="promise_in_bounds")
        pool_v = pool_v.at[pids, slots].set(v_new[:, 0],
                                            mode="promise_in_bounds")
        o = kops.paged_attention(q[:, 0], pool_k, pool_v, page_table,
                                 positions, window=window,
                                 cap=cfg.attn_softcap, mode=kernel)[:, None]
    dot_o = dot or (lambda a, w, name: jnp.einsum(
        "bsnh,nhd->bsd", a, w))
    return dot_o(o, p["wo"], "attn_o"), pool_k, pool_v


def attention_prefill_paged(p, x, pool_k, pool_v, page_table, positions,
                            kind: str, cfg, *, dot=None, kernel: str = "auto"):
    """Chunked prefill against a paged KV pool (prefill-with-cache).

    x           (B, Sq, D)  one prompt chunk's activations per sequence
    pool_k/v    (P, page, K, hd)  this layer's physical page pool (or the
                quantized ``{"q", "scale"}`` dicts, see below)
    page_table  (B, n_pages) int32; unused tails -> scratch page 0
    positions   (B,) int32  absolute position of each chunk's FIRST token
                (== the number of prompt tokens already resident in the
                pool for that sequence)

    The chunk's roped k/v are scattered into their pages first — token t
    at page ``page_table[b, (pos+t) // page]`` slot ``(pos+t) % page`` —
    then attention walks the sequence's pages with the chunked-prefill
    kernel: query t attends causally to every pool slot at
    ``kpos <= positions[b] + t``, i.e. the resident prompt prefix plus the
    chunk itself. No dense chronological prompt KV view is materialized on
    any path, and the final chunk's padding garbage stays behind the
    causal mask exactly like bucket padding did (overwritten by decode in
    position order).

    Quantized pools quantize the chunk on write (per-token per-head
    scales, the same mapping as the decode scatter) and run the
    fused-dequant prefill walk.

    Returns (out (B, Sq, D), pool_k, pool_v).
    """
    quantized = isinstance(pool_k, dict)
    page = (pool_k["q"] if quantized else pool_k).shape[1]
    B, Sq, _ = x.shape
    n_blocks = page_table.shape[1]
    abs_pos = positions[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    q, k_new, v_new = qkv(p, x, cfg.rope_theta, abs_pos, dot=dot)
    # A final chunk padded past the page-table width routes its overflow
    # rows to the scratch page explicitly: an unclamped gather fills OOB
    # indices with INT_MIN, which the promise_in_bounds scatter below
    # would treat as undefined behaviour.
    blocks = abs_pos // page                                    # (B, Sq)
    pids = jnp.take_along_axis(page_table,
                               jnp.minimum(blocks, n_blocks - 1), axis=1)
    pids = jnp.where(blocks < n_blocks, pids, 0)
    slots = abs_pos % page
    window = cfg.window_size if kind == "local" else 0
    if quantized:
        hd = q.shape[-1]
        bits = kref.kv_bits_of(pool_k["q"], hd)

        def write(pool, new):                        # new: (B, Sq, K, hd)
            qv, sc = kref.quantize_kv(new, bits)
            return {"q": pool["q"].at[pids, slots].set(
                        qv, mode="promise_in_bounds"),
                    "scale": pool["scale"].at[pids, slots].set(
                        sc, mode="promise_in_bounds")}

        pool_k = write(pool_k, k_new)
        pool_v = write(pool_v, v_new)
        o = kops.paged_attention_prefill_quant(
            q, pool_k["q"], pool_k["scale"], pool_v["q"], pool_v["scale"],
            page_table, positions, window=window, cap=cfg.attn_softcap,
            mode=kernel)
    else:
        pool_k = pool_k.at[pids, slots].set(k_new, mode="promise_in_bounds")
        pool_v = pool_v.at[pids, slots].set(v_new, mode="promise_in_bounds")
        o = kops.paged_attention_prefill(q, pool_k, pool_v, page_table,
                                         positions, window=window,
                                         cap=cfg.attn_softcap, mode=kernel)
    dot_o = dot or (lambda a, w, name: jnp.einsum(
        "bsnh,nhd->bsd", a, w))
    return dot_o(o, p["wo"], "attn_o"), pool_k, pool_v


def cross_attention(p, x, mem_k, mem_v, cfg, *, dot=None) -> jax.Array:
    """Decoder cross-attention against precomputed encoder k/v (no mask)."""
    B, S, D = x.shape
    if dot is None:
        dot = lambda a, w, name: jnp.einsum(
            "bsd,dnh->bsnh", a, w)
    q = dot(x, p["wq"], "xattn_q")
    T = mem_k.shape[1]
    if S >= flash_lib.FLASH_MIN or T >= 4 * flash_lib.FLASH_MIN:
        o = flash_lib.flash_attention(q, mem_k, mem_v, "bidir", 0,
                                      cfg.attn_softcap)
    else:
        mask = jnp.ones((1, 1, S, T), bool)
        o = _attend(q, mem_k, mem_v, mask, cfg.attn_softcap)
    dot_o = lambda a, w, name: jnp.einsum(
        "bsnh,nhd->bsd", a, w)
    return dot_o(o, p["wo"], "xattn_o")


def cross_kv(p, mem, *, dot=None):
    """Precompute encoder-side k/v for cross attention (no rope)."""
    if dot is None:
        dot = lambda a, w, name: jnp.einsum(
            "bsd,dnh->bsnh", a, w)
    return dot(mem, p["wk"], "xattn_k"), dot(mem, p["wv"], "xattn_v")


def cache_len_for(kind: str, cfg, seq_len: int) -> int:
    if kind == "local":
        return min(cfg.window_size, seq_len)
    return seq_len
