"""Generic decoder-only LM assembly for the dense / moe / vlm / ssm / hybrid
families. Layers are scanned in groups of ``period`` sub-layers, where period
is the LCM of the attention pattern (gemma2 local/global) and the MoE
interleave (llama4 dense/MoE) — each sub-layer slot has its own stacked
parameter pytree so `lax.scan` keeps HLO size and CPU compile time bounded
for the 88-layer/123B configs.

Public surface (used by models/api.py):
  param_defs(cfg)                     -> PDef pytree
  forward(params, batch, cfg, ...)    -> (logits, caches|None, aux)
  decode_step(params, cache, token, pos, cfg) -> (logits, new_cache)
  cache_specs(cfg, batch, seq_len)    -> ShapeDtypeStruct pytree
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed_defs, ffn_apply, ffn_defs, norm_def,
                                 rms_norm, softcap)
from repro.models.params import PDef, stacked

F32 = jnp.float32
Ac = Callable[[jax.Array, str], jax.Array]  # activation-sharding hook


def _identity_ac(x, kind):
    return x


# ------------------------------------------------------------- structure ----
def period_of(cfg) -> int:
    p = len(cfg.attn_pattern)
    if cfg.moe:
        p = math.lcm(p, cfg.moe.every)
    return p


def sublayer_kinds(cfg):
    """Static description of each sub-layer slot within a period."""
    P = period_of(cfg)
    kinds = []
    for j in range(P):
        kinds.append({
            "attn": cfg.attn_pattern[j % len(cfg.attn_pattern)],
            "moe": cfg.is_moe_layer(j),
        })
    return kinds


def hybrid_groups(cfg):
    """zamba2: sizes of mamba-layer groups between shared-attn applications."""
    k = cfg.shared_attn_every
    L = cfg.num_layers
    sizes = []
    while L > 0:
        sizes.append(min(k, L))
        L -= k
    return sizes


# ------------------------------------------------------------ param defs ----
def _dense_sublayer_defs(cfg, kind) -> dict:
    d = cfg.d_model
    defs: Dict[str, Any] = {
        "ln1": norm_def(d),
        "attn": attn.attn_defs(d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim),
        "ln2": norm_def(d),
    }
    if kind["moe"]:
        defs["moe"] = moe_lib.moe_defs(d, cfg.moe)
    else:
        defs["ffn"] = ffn_defs(d, cfg.d_ff, cfg.activation)
    if cfg.sandwich_norm:
        defs["ln1_post"] = norm_def(d)
        defs["ln2_post"] = norm_def(d)
    return defs


def param_defs(cfg) -> dict:
    d = cfg.d_model
    defs: Dict[str, Any] = {"embed": embed_defs(cfg.padded_vocab, d),
                            "final_norm": norm_def(d)}
    if not cfg.tie_embeddings:
        defs["lm_head"] = PDef((d, cfg.padded_vocab), ("embed", "vocab"),
                               "scaled")
    if cfg.frontend == "vision_stub":
        defs["frontend_proj"] = PDef((d, d), ("embed", "embed2"), "scaled")

    if cfg.family == "ssm":
        defs["mamba"] = stacked({"m": ssm_lib.mamba_defs(cfg)},
                                cfg.num_layers)["m"]
        defs["mamba_ln"] = stacked({"m": norm_def(d)}, cfg.num_layers)["m"]
    elif cfg.family == "hybrid":
        defs["mamba"] = stacked({"m": ssm_lib.mamba_defs(cfg)},
                                cfg.num_layers)["m"]
        defs["mamba_ln"] = stacked({"m": norm_def(d)}, cfg.num_layers)["m"]
        defs["shared"] = {
            "fuse_in": PDef((2 * d, d), ("embed2", "embed"), "scaled"),
            "fuse_out": PDef((d, d), ("embed2", "embed"), "scaled"),
            **_dense_sublayer_defs(cfg, {"attn": "global", "moe": False}),
        }
    else:
        P = period_of(cfg)
        kinds = sublayer_kinds(cfg)
        n_groups = cfg.num_layers // P
        assert cfg.num_layers % P == 0, (cfg.name, cfg.num_layers, P)
        defs["blocks"] = {
            f"sub{j}": stacked(_dense_sublayer_defs(cfg, kinds[j]), n_groups)
            for j in range(P)
        }
    return defs


# ----------------------------------------------------------------- blocks ----
def _dense_block_fwd(p, x, kind, cfg, positions, ac: Ac, dot=None,
                     want_cache=True, ring=True):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, cache = attn.attention_fwd(p["attn"], h, kind["attn"], cfg, positions,
                                  dot=dot, ring=ring)
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = ac(x + a, "resid")
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind["moe"]:
        f, aux = moe_lib.moe_apply(p["moe"], h, cfg.moe, cfg.activation,
                                   dot=dot, ac=ac)
    else:
        f, aux = ffn_apply(p["ffn"], h, cfg.activation, dot=dot), 0.0
    if cfg.sandwich_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    x = ac(x + f, "resid")
    if want_cache and ring and kind["attn"] == "local":
        W = cfg.window_size
        cache = {"k": _to_ring(cache["k"], W), "v": _to_ring(cache["v"], W)}
    return x, (cache if want_cache else None), aux


def _to_ring(k: jax.Array, W: int) -> jax.Array:
    S = k.shape[1]
    if S >= W:
        return attn._last_window_ring(k, W)
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, W - S)
    return jnp.pad(k, pad)


def _dense_block_decode(p, x, cache, pos, kind, cfg, dot=None, ac=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, ck, cv = attn.attention_decode(p["attn"], h, cache["k"], cache["v"],
                                      pos, kind["attn"], cfg, dot=dot, ac=ac)
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind["moe"]:
        f, _ = moe_lib.moe_apply(p["moe"], h, cfg.moe, cfg.activation,
                                 dot=dot)
    else:
        f = ffn_apply(p["ffn"], h, cfg.activation, dot=dot)
    if cfg.sandwich_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    return x + f, {"k": ck, "v": cv}


def _shared_block_fwd(p, x, emb, cfg, positions, ac, dot=None,
                      want_cache=True):
    u = jnp.concatenate([x, emb], axis=-1)
    u = jnp.einsum("bsd,de->bse", u, p["fuse_in"])
    u, cache, _ = _dense_block_fwd(
        p, u, {"attn": "global", "moe": False}, cfg, positions, ac, dot=dot,
        want_cache=want_cache)
    v = jnp.einsum("bsd,de->bse", u, p["fuse_out"])
    return ac(x + v, "resid"), cache


def _shared_block_decode(p, x, emb, cache, pos, cfg, dot=None, ac=None):
    u = jnp.concatenate([x, emb], axis=-1)
    u = jnp.einsum("bsd,de->bse", u, p["fuse_in"])
    u, cache = _dense_block_decode(p, u, cache, pos,
                                   {"attn": "global", "moe": False}, cfg,
                                   dot=dot, ac=ac)
    v = jnp.einsum("bsd,de->bse", u, p["fuse_out"])
    return x + v, cache


# ---------------------------------------------------------------- embed ----
def embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _assemble_input(params, batch, cfg, ac: Ac):
    """Returns (x (B,S,D), loss_mask (B,S) or None)."""
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(jnp.bfloat16)
        pe = jnp.einsum("bsd,de->bse", patches, params["frontend_proj"])
        te = embed_tokens(params, batch["tokens"], cfg)
        x = jnp.concatenate([pe, te], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(pe.shape[:2], F32), jnp.ones(te.shape[:2], F32)],
            axis=1)
        return ac(x, "resid"), mask
    x = embed_tokens(params, batch["tokens"], cfg)
    return ac(x, "resid"), None


def unembed(params, x, cfg, *, dot=None):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    dot = dot or (lambda a, ww, name: jnp.einsum(
        "bsd,dv->bsv", a, ww, preferred_element_type=jnp.float32))
    logits = softcap(dot(x, w, "lm_head").astype(F32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding columns
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e9)
    return logits


# ------------------------------------------------------------ chunked CE ----
def chunked_ce(params, hidden, labels, cfg, *, dot=None, chunk: int = 256,
               loss_mask=None):
    """Next-token CE without materializing (B,S,V) logits: unembed + softmax
    run per seq-chunk inside a rematerialized scan, so peak live memory is
    (B, chunk, V) instead of (B, S, V) — the difference between fitting and
    not fitting 16GiB/chip for the 256k-vocab archs."""
    xs = hidden[:, :-1]
    ls = labels[:, 1:]
    B, n, D = xs.shape
    mask = jnp.ones((B, n), F32) if loss_mask is None \
        else loss_mask[:, 1:].astype(F32)
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        ls = jnp.pad(ls, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (n + pad) // chunk
    xs = jnp.moveaxis(xs.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(ls.reshape(B, nc, chunk), 1, 0)
    mask = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        xc, lc, mc = inp
        logits = unembed(params, xc, cfg, dot=dot)          # (B,chunk,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum((logz - gold) * mc), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), F32),
                                        jnp.zeros((), F32)), (xs, ls, mask))
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------- forward ----
def forward(params, batch, cfg, *, want_cache: bool, remat: bool = False,
            ac: Ac = _identity_ac, dot=None, unembed_mode: str = "full",
            cache_layout: str = "ring"):
    """Full-sequence forward (training / prefill).

    unembed_mode: "full" -> logits (B,S,V); "last" -> logits (B,1,V) (prefill);
    "none" -> final hidden states (B,S,D) (training loss path).
    cache_layout: "ring" -> local-attention caches in ring layout (dense
    decode); "full" -> chronological full-length caches (paged engine).
    Returns (logits_or_hidden, caches or None, aux scalar, loss_mask).
    """
    ring = cache_layout == "ring"
    x, loss_mask = _assemble_input(params, batch, cfg, ac)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux_total = jnp.zeros((), F32)
    caches: Dict[str, Any] = {}

    if cfg.family in ("ssm", "hybrid"):
        emb0 = x

        def mamba_body(carry, xs):
            h = carry
            pm, ln = xs
            y, cache = ssm_lib.mamba_block_fwd(
                pm, rms_norm(h, ln, cfg.norm_eps), cfg, dot=dot)
            return ac(h + y, "resid"), (cache if want_cache else None)

        body = jax.checkpoint(mamba_body) if remat else mamba_body

        if cfg.family == "ssm":
            x, mcache = jax.lax.scan(body, x,
                                     (params["mamba"], params["mamba_ln"]))
            caches["mamba"] = mcache
        else:
            sizes = hybrid_groups(cfg)
            shared_caches, mamba_caches = [], []
            off = 0
            for g, size in enumerate(sizes):
                x, sc = _shared_block_fwd(params["shared"], x, emb0, cfg,
                                          positions, ac, dot=dot,
                                          want_cache=want_cache)
                shared_caches.append(sc)
                sl = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, off, off + size, axis=0),
                    (params["mamba"], params["mamba_ln"]))
                x, mc = jax.lax.scan(body, x, sl)
                mamba_caches.append(mc)
                off += size
            if want_cache:
                caches["shared"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *shared_caches)
                caches["mamba"] = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *mamba_caches)
    else:
        P = period_of(cfg)
        kinds = sublayer_kinds(cfg)

        def group_body(carry, xs):
            h, aux = carry
            outs = {}
            for j in range(P):
                h, outs[f"sub{j}"], aux_j = _dense_block_fwd(
                    xs[f"sub{j}"], h, kinds[j], cfg, positions, ac, dot=dot,
                    want_cache=want_cache, ring=ring)
                aux = aux + aux_j
            return (h, aux), (outs if want_cache else None)

        body = jax.checkpoint(group_body) if remat else group_body
        (x, aux_total), gcaches = jax.lax.scan(
            body, (x, aux_total), params["blocks"])
        if want_cache:
            caches.update(gcaches)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if unembed_mode == "none":
        return x, (caches if want_cache else None), aux_total, loss_mask
    if unembed_mode == "last":
        x = x[:, -1:]
    logits = unembed(params, x, cfg, dot=dot)
    return logits, (caches if want_cache else None), aux_total, loss_mask


# ----------------------------------------------------------------- decode ----
def decode_step(params, cache, token, pos, cfg, *, ac: Ac = _identity_ac,
                dot=None):
    """token (B,1) int32, pos scalar int32. Returns (logits (B,1,V), cache)."""
    x = embed_tokens(params, token, cfg)
    emb0 = x

    if cfg.family in ("ssm", "hybrid"):
        def mamba_body(h, xs):
            pm, ln, c = xs
            y, nc = ssm_lib.mamba_block_decode(
                pm, rms_norm(h, ln, cfg.norm_eps), c, cfg, dot=dot)
            return h + y, nc

        if cfg.family == "ssm":
            x, mcache = jax.lax.scan(
                mamba_body, x,
                (params["mamba"], params["mamba_ln"], cache["mamba"]))
            new_cache = {"mamba": mcache}
        else:
            sizes = hybrid_groups(cfg)
            new_shared, new_mamba = [], []
            off = 0
            for g, size in enumerate(sizes):
                sc = jax.tree.map(lambda a: a[g], cache["shared"])
                x, nsc = _shared_block_decode(params["shared"], x, emb0, sc,
                                              pos, cfg, dot=dot, ac=ac)
                new_shared.append(nsc)
                sl = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, off, off + size, axis=0),
                    (params["mamba"], params["mamba_ln"]))
                mc = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, off, off + size, axis=0),
                    cache["mamba"])
                x, nmc = jax.lax.scan(mamba_body, x, sl + (mc,))
                new_mamba.append(nmc)
                off += size
            new_cache = {
                "shared": jax.tree.map(lambda *xs: jnp.stack(xs), *new_shared),
                "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                      *new_mamba),
            }
    else:
        P = period_of(cfg)
        kinds = sublayer_kinds(cfg)

        def group_body(h, xs):
            blocks, caches_g = xs
            new_g = {}
            for j in range(P):
                h, new_g[f"sub{j}"] = _dense_block_decode(
                    blocks[f"sub{j}"], h, caches_g[f"sub{j}"], pos, kinds[j],
                    cfg, dot=dot, ac=ac)
            return h, new_g

        x, gcaches = jax.lax.scan(
            group_body, x, (params["blocks"],
                            {k: cache[k] for k in cache if k.startswith("sub")}))
        new_cache = gcaches

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg, dot=dot)
    return logits, new_cache


# ----------------------------------------------------------- paged decode ----
def _dense_block_decode_paged(p, x, pool_kv, page_table, positions, kind, cfg,
                              dot=None, ac=None, kernel="auto"):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, ck, cv = attn.attention_decode_paged(
        p["attn"], h, pool_kv["k"], pool_kv["v"], page_table, positions,
        kind["attn"], cfg, dot=dot, ac=ac, kernel=kernel)
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind["moe"]:
        f, _ = moe_lib.moe_apply(p["moe"], h, cfg.moe, cfg.activation,
                                 dot=dot)
    else:
        f = ffn_apply(p["ffn"], h, cfg.activation, dot=dot)
    if cfg.sandwich_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    return x + f, {"k": ck, "v": cv}


def decode_step_paged(params, pool, page_table, token, positions, cfg, *,
                      ac: Ac = _identity_ac, dot=None, kernel="auto"):
    """Batched slot-indexed decode against a paged KV pool.

    token (B,1) int32; positions (B,) int32 per-sequence absolute positions
    (continuous batching: every batch slot may be at a different depth);
    pool is the pytree from ``pool_specs`` and page_table (B, n_pages) maps
    each sequence's logical blocks to physical pages (shared across layers).
    ``kernel`` selects the paged-attention path (see attention_decode_paged)
    — every choice walks pages block-by-block; no layer materializes the
    dense chronological KV view, and local layers trim the walk to their
    window. Returns (logits (B,1,V), new_pool).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged decode supports attention-cache families only, "
            f"got {cfg.family!r}")
    x = embed_tokens(params, token, cfg)
    P = period_of(cfg)
    kinds = sublayer_kinds(cfg)

    def group_body(h, xs):
        blocks, pool_g = xs
        new_g = {}
        for j in range(P):
            h, new_g[f"sub{j}"] = _dense_block_decode_paged(
                blocks[f"sub{j}"], h, pool_g[f"sub{j}"], page_table,
                positions, kinds[j], cfg, dot=dot, ac=ac, kernel=kernel)
        return h, new_g

    x, new_pool = jax.lax.scan(group_body, x, (params["blocks"], pool))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg, dot=dot)
    return logits, new_pool


# --------------------------------------------------------- paged prefill ----
def _dense_block_prefill_paged(p, x, pool_kv, page_table, positions, kind,
                               cfg, dot=None, kernel="auto"):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, ck, cv = attn.attention_prefill_paged(
        p["attn"], h, pool_kv["k"], pool_kv["v"], page_table, positions,
        kind["attn"], cfg, dot=dot, kernel=kernel)
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind["moe"]:
        f, _ = moe_lib.moe_apply(p["moe"], h, cfg.moe, cfg.activation,
                                 dot=dot)
    else:
        f = ffn_apply(p["ffn"], h, cfg.activation, dot=dot)
    if cfg.sandwich_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    return x + f, {"k": ck, "v": cv}


def prefill_chunk_paged(params, pool, page_table, tokens, positions, cfg, *,
                        dot=None, kernel="auto"):
    """One chunked-prefill step: run ``tokens`` (B, Sq) — a contiguous
    prompt chunk whose first token sits at absolute position
    ``positions[b]`` — through every layer, writing each layer's chunk K/V
    into the paged pool and attending over the pool itself (resident
    prompt prefix + the chunk, causal within the chunk). The engine calls
    this once per tick per mid-prefill sequence, so one long prompt costs
    many small ticks instead of one decode-stalling bucket.

    Returns (hidden (B, Sq, D) final-norm hidden states, new_pool) — the
    caller unembeds only the rows it needs (the last real prompt position
    of the final chunk; intermediate chunks need no logits at all).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged prefill supports attention-cache families only, "
            f"got {cfg.family!r}")
    x = embed_tokens(params, tokens, cfg)
    P = period_of(cfg)
    kinds = sublayer_kinds(cfg)

    def group_body(h, xs):
        blocks, pool_g = xs
        new_g = {}
        for j in range(P):
            h, new_g[f"sub{j}"] = _dense_block_prefill_paged(
                blocks[f"sub{j}"], h, pool_g[f"sub{j}"], page_table,
                positions, kinds[j], cfg, dot=dot, kernel=kernel)
        return h, new_g

    x, new_pool = jax.lax.scan(group_body, x, (params["blocks"], pool))
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_pool


def normalize_kv_bits(cfg, kv_bits) -> Optional[Tuple[int, ...]]:
    """Canonicalize a KV bit spec to one entry per sub-layer slot.

    Accepts None (fp pool), an int (uniform), a dict keyed ``sub{j}`` or
    ``kv_sub{j}`` (the HAQ site names — a searched policy round-trips
    as-is; missing slots default to 16, unknown keys are rejected rather
    than silently dropping quantization), or a sequence cycled over the
    period like ``attn_pattern``. All-16 collapses to None so the fp pool
    layout (and its bit-exact serving path) stays the default
    representation."""
    if kv_bits is None:
        return None
    P = period_of(cfg)
    if isinstance(kv_bits, int):
        bits = (kv_bits,) * P
    elif isinstance(kv_bits, dict):
        by_slot = {}
        for key, v in kv_bits.items():
            slot = key[3:] if key.startswith("kv_sub") else key
            j = int(slot[3:]) if slot.startswith("sub") \
                and slot[3:].isdigit() else -1
            if not 0 <= j < P:
                raise ValueError(f"unknown KV policy key {key!r} "
                                 f"(period-{P} pool has sub0..sub{P - 1})")
            by_slot[j] = int(v)
        bits = tuple(by_slot.get(j, 16) for j in range(P))
    else:
        seq = tuple(int(b) for b in kv_bits)
        if not seq or P % len(seq):
            raise ValueError(f"kv_bits length {len(seq)} does not cycle "
                             f"into period {P}")
        bits = tuple(seq[j % len(seq)] for j in range(P))
    for b in bits:
        if b not in (4, 8, 16):
            raise ValueError(f"KV bits must be 4, 8 or 16, got {b}")
    if all(b == 16 for b in bits):
        return None
    if any(b == 4 for b in bits) and cfg.resolved_head_dim % 2:
        raise ValueError("int4 KV packs two codes per byte along head_dim; "
                         f"head_dim={cfg.resolved_head_dim} is odd")
    return bits


def pool_specs(cfg, num_pages: int, page_size: int, kv_bits=None):
    """Abstract paged-KV-pool pytree: per sub-layer slot, k/v pools of shape
    (n_groups, num_pages, page_size, K, hd). Page ids are shared across
    layers — one logical page allocation covers every layer's pool. Local
    (sliding-window) layers use the same full-length pages and are masked to
    the window at attention time; the engine frees pages behind the window
    when every layer is local (serving/engine/scheduler.py::trim_window).

    ``kv_bits`` (see normalize_kv_bits) selects the HAQ KV-quantized layout
    per sub-layer slot: 16 keeps the bf16 arrays; 8/4 store
    ``{"q": int8 (n_groups, num_pages, page_size, K, hd_store),
       "scale": fp32 (n_groups, num_pages, page_size, K)}``
    with hd_store = hd for int8 and hd//2 for int4 (two codes per byte
    packed along head_dim). Scales are per page slot (token) and per kv
    head — each physical page carries its own (page_size, K) scale tile, so
    quantize-on-write never re-scales resident tokens (see
    serving/kvquant)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged KV pool supports attention-cache families only, "
            f"got {cfg.family!r}")
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    P = period_of(cfg)
    n_groups = cfg.num_layers // P
    bits = normalize_kv_bits(cfg, kv_bits) or (16,) * P

    def kv_spec(b):
        if b == 16:
            return jax.ShapeDtypeStruct(
                (n_groups, num_pages, page_size, K, hd), jnp.bfloat16)
        hd_store = hd if b == 8 else hd // 2
        return {
            "q": jax.ShapeDtypeStruct(
                (n_groups, num_pages, page_size, K, hd_store), jnp.int8),
            "scale": jax.ShapeDtypeStruct(
                (n_groups, num_pages, page_size, K), jnp.float32),
        }

    return {f"sub{j}": {"k": kv_spec(bits[j]), "v": kv_spec(bits[j])}
            for j in range(P)}


def pool_axes(cfg, kv_bits=None):
    """Logical-axis pytree matching ``pool_specs`` (for the SPMD serving
    engine). ``kv_heads`` is the only mesh-mapped axis: the page and
    page-slot dims stay unsharded because the paged-attention walk's online
    softmax must keep its single-device reduction order (bit-exact serving),
    and pages are the host allocator's unit — one logical page id covers
    every shard's kv-head slice of that page. The dense decode path's
    ``cache_seq`` fall-through (see distributed.sharding.CANDIDATES) does
    not apply here for the same reason."""
    kv = ("layer", None, None, "kv_heads", "head_dim")
    scale = ("layer", None, None, "kv_heads")
    return jax.tree.map(
        lambda s: kv if s.ndim == 5 else scale,
        pool_specs(cfg, 2, 2, kv_bits=kv_bits))


# ------------------------------------------------------------ cache specs ----
def cache_specs(cfg, batch: int, seq_len: int):
    """Abstract decode-cache pytree for dry-run lowering / allocation."""
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads

    def kv(T, lead):
        return {
            "k": jax.ShapeDtypeStruct(lead + (batch, T, K, hd), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(lead + (batch, T, K, hd), jnp.bfloat16),
        }

    if cfg.family == "ssm":
        one = ssm_lib.mamba_cache_spec(cfg, batch)
        return {"mamba": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape,
                                           s.dtype), one)}
    if cfg.family == "hybrid":
        one = ssm_lib.mamba_cache_spec(cfg, batch)
        n_apps = len(hybrid_groups(cfg))
        return {
            "mamba": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape,
                                               s.dtype), one),
            "shared": kv(seq_len, (n_apps,)),
        }
    P = period_of(cfg)
    kinds = sublayer_kinds(cfg)
    n_groups = cfg.num_layers // P
    out = {}
    for j in range(P):
        T = attn.cache_len_for(kinds[j]["attn"], cfg, seq_len)
        out[f"sub{j}"] = kv(T, (n_groups,))
    return out


def init_cache(cfg, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, seq_len))


def cache_axes(cfg):
    """Logical-axis pytree matching cache_specs (for sharding)."""
    kv_ax = {"k": ("layer", "batch", "cache_seq", "kv_heads", "head_dim"),
             "v": ("layer", "batch", "cache_seq", "kv_heads", "head_dim")}
    mamba_ax = {"conv": ("layer", "batch", "conv", "ssm_inner"),
                "state": ("layer", "batch", "ssm_heads", "head_dim",
                          "ssm_state")}
    if cfg.family == "ssm":
        return {"mamba": mamba_ax}
    if cfg.family == "hybrid":
        return {"mamba": mamba_ax, "shared": dict(kv_ax)}
    P = period_of(cfg)
    return {f"sub{j}": dict(kv_ax) for j in range(P)}
