"""Parameter-definition DSL.

Every model declares its parameters ONCE as a pytree of ``PDef`` leaves
(shape + logical axes + init). From that single declaration we derive:

  * ``init_params``     — materialized arrays (CPU smoke tests, examples)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run lowering, no allocation)
  * ``logical_specs``   — pytree of logical-axis tuples consumed by
                          ``repro.distributed.sharding`` to build NamedShardings

Logical axis vocabulary (see distributed/sharding.py for the mesh mapping):
  layer, vocab, embed, heads, kv_heads, qk_head_dim(=head_dim), d_ff,
  experts, expert_ff, ssm_inner, ssm_state, conv, batch, seq, null
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"     # normal | zeros | ones | scaled(fan_in)
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stacked(defs, n: int):
    """Prepend a scanned layer dimension to every PDef in a subtree."""
    def _s(d: PDef) -> PDef:
        return PDef((n,) + d.shape, ("layer",) + d.axes, d.init, d.scale,
                    d.dtype)
    return jax.tree.map(_s, defs, is_leaf=lambda x: isinstance(x, PDef))


def _is_pdef(x):
    return isinstance(x, PDef)


def init_params(defs, key, dtype=None):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_pdef)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = dtype or d.dtype
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dt)
        else:
            if d.init == "scaled":
                fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
                std = d.scale / math.sqrt(max(fan_in, 1))
            else:
                std = d.scale * 0.02
            a = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=None):
    def _a(d: PDef):
        return jax.ShapeDtypeStruct(d.shape, dtype or d.dtype)
    return jax.tree.map(_a, defs, is_leaf=_is_pdef)


def logical_specs(defs):
    def _l(d: PDef):
        return d.axes
    return jax.tree.map(_l, defs, is_leaf=_is_pdef)


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_pdef)
    return int(sum(np.prod(d.shape) for d in leaves))


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_pdef)
    return int(sum(np.prod(d.shape) * jnp.dtype(d.dtype).itemsize
                   for d in leaves))
