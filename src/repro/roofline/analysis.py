"""Three-term roofline analysis from a compiled XLA artifact.

Terms (seconds), per the assignment spec, for TPU v5e targets:
  compute    = HLO_FLOPs_global   / (chips * PEAK_FLOPS)
  memory     = HLO_bytes_global   / (chips * HBM_BW)
  collective = coll_bytes_global  / (chips * ICI_BW)

`cost_analysis()` reports the per-device (SPMD) program, so global = per-dev
* chips. Collective bytes are not in cost_analysis: we parse the optimized
post-partitioning HLO text and sum result-shape bytes of every collective op,
weighting all-reduce 2x (ring reduce-scatter + all-gather traffic).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12          # bf16
PEAK_FLOPS_INT8 = 394e12     # int8 MXU path
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~usable per-chip collective bw)
HBM_GB = 16.0                # per chip
# energy model constants (HAQ-style feedback; public-literature scale values)
PJ_PER_FLOP_BF16 = 0.25e-12 * 1e12 / 1e12  # ~0.25 pJ/flop
PJ_PER_BYTE_HBM = 120e-12                  # ~120 pJ/byte DRAM access

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Sum bytes over every array literal in an HLO result type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op kind, from optimized HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (" +
                     "|".join(COLLECTIVES) + r")\(", line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        if op == "all-reduce":
            b *= 2.0  # ring AR = RS + AG
        out[op] += b
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k in COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_global / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization if the step ran exactly at the dominant
        roofline term (the score we hillclimb)."""
        if not self.t_bound:
            return 0.0
        return self.model_flops / (self.t_bound * self.chips * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode per step)."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        toks = shape.tokens
        if cfg.is_encdec:
            toks = shape.global_batch * (shape.seq_len
                                         + shape.seq_len // cfg.dec_ratio)
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch


def active_params(cfg) -> int:
    """Per-token active parameter count (MoE counts top-k experts only)."""
    total = cfg.param_count()
    if not cfg.moe:
        return total
    m = cfg.moe
    gated = cfg.activation in ("swiglu", "geglu")
    per_expert = cfg.d_model * m.d_ff_expert * (3 if gated else 2)
    n_moe_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
    inactive = n_moe_layers * (m.num_experts - m.experts_per_token) * per_expert
    return total - inactive


def analyze(compiled, chips: int, cfg=None, shape=None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Legacy raw-cost_analysis variant (undercounts while bodies)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    mf = model_flops_for(cfg, shape) if cfg is not None else 0.0
    return Roofline(
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        coll_bytes_global=coll["total"] * chips,
        chips=chips,
        model_flops=mf,
    )


# --------------------------------------------------- analytic memory model ----
def analytic_memory_bytes(cfg, shape, *, weight_bits: float = 16.0,
                          quantized_moments: bool = False) -> float:
    """Global HBM traffic per step (bytes). XLA's cost_analysis 'bytes
    accessed' is fusion-dependent AND undercounts loop bodies, so the memory
    roofline term uses this explicit model (coefficients documented inline;
    EXPERIMENTS.md §Roofline).

    weight_bits: effective stored weight precision (HAQ policies lower it)."""
    P_act = float(active_params(cfg))
    P = float(cfg.param_count())
    d, L = cfg.d_model, cfg.num_layers
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    wb = weight_bits / 8.0                      # bytes per weight
    hd = cfg.resolved_head_dim
    H, K = max(cfg.num_heads, 1), max(cfg.num_kv_heads, 1)

    if shape.kind == "train":
        # weights: fwd read + bwd read + remat re-read (bf16)
        w_stream = 3 * 2 * P
        # grads fp32 write+read; master r/w; moments r/w (fp32 or int8)
        opt = 2 * 4 * P + 2 * 4 * P + (2 * 2 * P if quantized_moments
                                       else 2 * 8 * P) + 2 * P
        # residual stream: ~4 r/w per layer fwd, ~6 with remat bwd
        acts = tokens * d * 2 * L * 10
        # flash KV re-streaming: k/v re-read per q block, fwd + 2 bwd passes
        nq = max(S // 512, 1)
        attn = L * B * S * (2 * K) * hd * 2 * nq * 3 if H else 0
        # chunked CE: lm_head re-read per 256-token chunk, fwd + bwd recompute
        nchunk = max(S // 256, 1)
        ce = d * cfg.padded_vocab * 2 * nchunk * 3
        return w_stream + opt + acts + attn + ce
    if shape.kind == "prefill":
        w_stream = 2 * P_act if cfg.moe else wb * P
        acts = tokens * d * 2 * L * 4
        nq = max(S // 512, 1)
        attn = L * B * S * (2 * K) * hd * 2 * nq if H else 0
        cache = _cache_bytes(cfg, B, S)
        return w_stream + acts + attn + cache
    # decode: one token; weights + cache dominate
    w_stream = wb * P_act
    cache = _cache_bytes(cfg, B, S) * 1.02      # full read + tiny write
    return w_stream + cache + B * d * 2 * L * 6


def _cache_bytes(cfg, B: int, S: int) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        s = cfg.ssm
        return cfg.num_layers * B * (cfg.ssm_heads * s.head_dim * s.d_state
                                     * 4 + 3 * (cfg.d_inner + 2 * s.n_groups
                                                * s.d_state) * 2)
    if cfg.family == "hybrid":
        s = cfg.ssm
        ssm = cfg.num_layers * B * (cfg.ssm_heads * s.head_dim * s.d_state * 4)
        n_apps = -(-cfg.num_layers // cfg.shared_attn_every)
        return ssm + n_apps * B * S * cfg.num_kv_heads * hd * 2 * 2
    total = 0.0
    from repro.models.transformer import period_of, sublayer_kinds
    P = period_of(cfg)
    for j, kind in enumerate(sublayer_kinds(cfg)):
        T = min(cfg.window_size, S) if kind["attn"] == "local" else S
        total += (cfg.num_layers // P) * B * T * cfg.num_kv_heads * hd * 2 * 2
    if cfg.is_encdec:
        total += cfg.num_layers * B * S * cfg.num_kv_heads * hd * 2 * 2
    return total


def analyze_hlo_aware(hlo_text: str, chips: int, cfg, shape, *,
                      weight_bits: float = 16.0,
                      quantized_moments: bool = False) -> Roofline:
    """Three-term roofline with loop-aware compute/collective terms (parsed
    from the per-device HLO with while-trip multipliers) and the analytic
    memory model above."""
    from repro.roofline.hlo_costs import analyze_hlo
    out = analyze_hlo(hlo_text)
    return Roofline(
        flops_global=out["dot_flops"] * chips,
        bytes_global=analytic_memory_bytes(
            cfg, shape, weight_bits=weight_bits,
            quantized_moments=quantized_moments),
        coll_bytes_global=out["coll_bytes"] * chips,
        chips=chips,
        model_flops=model_flops_for(cfg, shape),
    )
