"""While-aware HLO cost attribution.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — but every
layer stack here is a `lax.scan`, so FLOPs/bytes/collectives are undercounted
by ~num_layers (observed useful_flops_ratio up to 67x). This module reparses
the optimized HLO text and attributes costs with loop multipliers:

  * computations are parsed into (name -> instructions);
  * a call graph is walked from ENTRY; `while` bodies inherit
    multiplier x trip_count (trip count = the s32 constant in the loop
    condition computation — the canonical lax.scan lowering);
  * `dot` FLOPs are 2 * prod(result_dims) * prod(lhs_contracting_dims), with
    operand shapes resolved from the per-computation symbol table;
  * collective bytes follow repro.roofline.analysis.COLLECTIVES semantics
    (all-reduce weighted 2x).

This gives exact loop-aware compute/collective terms. HBM bytes remain
fusion-dependent; the memory term instead comes from the analytic model in
`analysis.analytic_memory_bytes` (documented in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "ragged-all-to-all")

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)(?:\.clone)? \(.*\) -> .+ \{\s*$")
_INSTR_RE = re.compile(r"^\s+(?:ROOT )?%?([\w.\-]+) = (.*)$")
_SHAPE_RE = re.compile(r"^\(?((?:\w+\[[\d,]*\]\S*(?:, )?)+)\)?")
_ONE_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_dims(type_str: str):
    """First array shape in a type string -> (dtype, dims list)."""
    m = _ONE_SHAPE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


def _all_shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _ONE_SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.shapes: Dict[str, Tuple[str, List[int]]] = {}


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        m = _COMP_RE.match(line)
        if m and "{" in line:
            name = m.group(1)
            cur = Computation(name)
            comps[name] = cur
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            cur.lines.append(line)
            name, rhs = mi.group(1), mi.group(2)
            dt, dims = _shape_dims(rhs)
            if dt:
                cur.shapes[name] = (dt, dims)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the loop condition (lax.scan lowering)."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"s32\[\] constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_CALL_RE = re.compile(
    r"(?:calls|to_apply|body)=%?([\w.\-]+)|condition=%?([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}")


def _dot_flops(comp: Computation, line: str) -> float:
    m = re.match(r"\s+(?:ROOT )?%?[\w.\-]+ = (\S+) dot\(%?([\w.\-]+), ", line)
    if not m:
        return 0.0
    out_type, lhs_name = m.group(1), m.group(2)
    _, out_dims = _shape_dims(out_type)
    n_out = 1
    for d in out_dims:
        n_out *= d
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    lhs = comp.shapes.get(lhs_name)
    if mc and lhs:
        for idx in mc.group(1).split(","):
            if idx:
                contract *= lhs[1][int(idx)]
    return 2.0 * n_out * contract


def _collective_bytes(line: str) -> Tuple[str, float]:
    m = re.match(r"\s+(?:ROOT )?%?[\w.\-]+ = (.+?) (" +
                 "|".join(COLLECTIVES) + r")\(", line)
    if not m:
        return "", 0.0
    b = _all_shape_bytes(m.group(1))
    op = m.group(2)
    if op == "all-reduce":
        b *= 2.0
    return op, b


def analyze_hlo(hlo: str) -> Dict[str, float]:
    """Loop-aware totals: {'dot_flops', 'coll_bytes', per-kind coll bytes,
    'coll_count'} for the per-device program."""
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return {"dot_flops": 0.0, "coll_bytes": 0.0, "coll_count": 0}
    totals = {"dot_flops": 0.0, "coll_bytes": 0.0, "coll_count": 0}
    for k in COLLECTIVES:
        totals[k] = 0.0

    seen_stack = set()

    def visit(comp: Computation, mult: float):
        if comp.name in seen_stack:  # defensive: no recursion in HLO
            return
        seen_stack.add(comp.name)
        for line in comp.lines:
            totals["dot_flops"] += _dot_flops(comp, line) * mult
            op, b = _collective_bytes(line)
            if op:
                totals[op] += b * mult
                totals["coll_bytes"] += b * mult
                totals["coll_count"] += 1
            # follow calls
            if " while(" in line:
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                trip = _trip_count(comps[mc.group(1)]) \
                    if mc and mc.group(1) in comps else 1
                if mb and mb.group(1) in comps:
                    visit(comps[mb.group(1)], mult * trip)
            else:
                for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      line):
                    callee = mm.group(1)
                    if callee in comps:
                        visit(comps[callee], mult)
                mb = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mb:
                    for callee in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                        if callee in comps:
                            visit(comps[callee], mult)
        seen_stack.discard(comp.name)

    visit(entry, 1.0)
    return totals
