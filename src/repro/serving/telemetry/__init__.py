"""Engine telemetry: per-tick tracing, a metrics registry, and roofline
predicted-vs-measured calibration.

Why this layer exists
---------------------
Every search loop in this repo (NAS, AMC, HAQ, the admission policy)
leans on `core/hardware_model`'s roofline as its fast feedback signal —
and the paper's method only holds if that signal is validated against
the real device. Before this package the engine had the inversion of
that: `admission.step_latency` *predicted* every tick, the engine
*measured* nothing but two bare lists, and no code path ever compared
the two. Telemetry closes the loop:

* **Tick trace** — every jitted dispatch (whole-prompt prefill, prompt
  chunk, batched decode) emits a typed `TickEvent` with fenced
  wall-clock duration (the engine blocks on the dispatch's outputs
  before stopping the timer, so async jit dispatch is never billed as
  compute) next to the roofline prediction for the same shape, plus
  batch composition, admissions, preemptions, page alloc/free/trim
  deltas, queue depth, pool watermarks, and per-shard mesh tags.
* **Sequence spans** — per request: enqueue -> admit -> chunk* ->
  first_token -> (preempt -> requeue -> ...)* -> finish/release,
  yielding real TTFT, queue-wait, and preemption history.
  ``Engine.stall_log`` / ``Engine.first_token_s`` survive as thin views
  over this record, so pre-telemetry tests and benches run unchanged.
* **Metrics registry** — counters/gauges/histograms (pool occupancy,
  fragmentation, free-page low-water mark, queue depth, preemptions,
  JitLRU hit/miss, per-kind tick latency). The default sink is a no-op
  (`sinks.NULL_SINK`), so the always-on path costs dataclass appends
  and integer bumps — no serialization, no export.
* **Exports + calibration** — Chrome trace-event JSON
  (`write_chrome_trace`, ``--trace-out`` in launch/serve.py, loadable
  in Perfetto), a text `summarize`, and `calibrate()`: per
  (tick kind, batch, q_len) least-squares scale factors and relative
  error of predicted vs measured — the correction `hardware_model`
  would need on this host, and the designated feedback input for the
  ROADMAP's serving-stack autotuner.

Reading a trace in Perfetto: open https://ui.perfetto.dev, drag the
``--trace-out`` JSON in. The "engine ticks" process shows one slice per
dispatch (click for measured vs predicted ms and page deltas), with
pool-free / queue-depth counter tracks above; the "requests" process
shows one span per request with instant marks at admit / chunk /
first_token / preempt.

Modules: `events` (typed event/span dataclasses), `metrics` (registry),
`sinks` (streaming extension point, NULL_SINK default), `recorder`
(the per-engine `Telemetry` object), `trace` (Chrome export + text
summary), `calibrate` (predicted-vs-measured fits).
"""
from repro.serving.telemetry.calibrate import (CalibrationGroup,
                                               CalibrationReport,
                                               ScaleLookup, calibrate)
from repro.serving.telemetry.events import (SEQ_EVENTS, TICK_KINDS, SeqEvent,
                                            SeqSpan, StallRecord, TickEvent)
from repro.serving.telemetry.metrics import (Counter, Gauge, Histogram,
                                             MetricsRegistry)
from repro.serving.telemetry.recorder import Telemetry
from repro.serving.telemetry.sinks import (NULL_SINK, NullSink,
                                           RecordingSink, Sink)
from repro.serving.telemetry.trace import (chrome_trace, summarize,
                                           write_chrome_trace)

__all__ = [
    "CalibrationGroup", "CalibrationReport", "ScaleLookup",
    "calibrate",
    "SEQ_EVENTS", "TICK_KINDS", "SeqEvent", "SeqSpan", "StallRecord",
    "TickEvent", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Telemetry", "NULL_SINK", "NullSink", "RecordingSink", "Sink",
    "chrome_trace", "summarize", "write_chrome_trace",
]
