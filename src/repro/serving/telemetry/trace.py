"""Chrome trace-event export + text summary.

`chrome_trace` renders a recorder's ticks and spans into the Trace Event
JSON format (the ``{"traceEvents": [...]}`` dict chrome://tracing and
https://ui.perfetto.dev load directly — see launch/serve.py
``--trace-out``). Layout:

* pid 1 ("engine ticks"): one complete ("ph":"X") slice per jitted
  dispatch on a thread per tick kind (prefill / chunk / decode), with
  measured vs predicted ms, batch composition, page deltas, and mesh
  tags in ``args`` — click a slice in Perfetto to read them;
* pid 1, counter tracks ("ph":"C"): pool free pages and queue depth
  sampled at every tick, drawn as area charts above the slices;
* pid 2 ("requests"): one async span ("ph":"b"/"e", id=rid) per request
  from enqueue to release, with instant marks ("ph":"n") for admit /
  chunk / first_token / preempt / requeue — the sequence lifecycle at a
  glance, stacked by request id.

Timestamps are microseconds since the trace clock (`Telemetry.t0`).
All values are finite by construction (`json.dumps(..., allow_nan=
False)` is asserted in tests), so the artifact always loads.
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.serving.telemetry.calibrate import calibrate
from repro.serving.telemetry.recorder import Telemetry

_TICK_TID = {"prefill": 1, "chunk": 2, "decode": 3}


def _base_time(tel: Telemetry) -> float:
    if tel.t0 is not None:
        return tel.t0
    times = [ev.t_start for ev in tel.ticks]
    times += [e.t for s in tel.spans.values() for e in s.events]
    return min(times) if times else 0.0


def chrome_trace(tel: Telemetry) -> Dict:
    """Render the recorder into a Trace Event Format dict."""
    t0 = _base_time(tel)
    us = lambda t: (t - t0) * 1e6
    evs: List[Dict] = [
        {"name": "process_name", "ph": "M", "pid": 1,
         "args": {"name": "engine ticks"}},
        {"name": "process_name", "ph": "M", "pid": 2,
         "args": {"name": "requests"}},
    ]
    for kind, tid in _TICK_TID.items():
        evs.append({"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                    "args": {"name": kind}})
    for ev in tel.ticks:
        args = {"measured_ms": ev.measured_s * 1e3,
                "predicted_ms": ev.predicted_s * 1e3,
                "batch": ev.batch, "padded_batch": ev.padded_batch,
                "q_len": ev.q_len, "tokens": ev.tokens,
                "rids": list(ev.rids), "step": ev.step,
                "admitted": ev.admitted, "preempted": ev.preempted,
                "pages_allocated": ev.pages_allocated,
                "pages_freed": ev.pages_freed,
                "pages_trimmed": ev.pages_trimmed}
        args.update(ev.tags)
        evs.append({"name": ev.kind, "cat": "tick", "ph": "X", "pid": 1,
                    "tid": _TICK_TID.get(ev.kind, 9), "ts": us(ev.t_start),
                    "dur": ev.measured_s * 1e6, "args": args})
        evs.append({"name": "pool free pages", "ph": "C", "pid": 1,
                    "ts": us(ev.t_start),
                    "args": {"free": ev.pool_free}})
        evs.append({"name": "queue depth", "ph": "C", "pid": 1,
                    "ts": us(ev.t_start),
                    "args": {"queued": ev.queue_depth}})
    for rid in sorted(tel.spans):
        span = tel.spans[rid]
        if not span.events:
            continue
        name = f"req {rid}"
        start = span.events[0].t
        end = span.events[-1].t
        evs.append({"name": name, "cat": "request", "ph": "b", "id": rid,
                    "pid": 2, "tid": 1, "ts": us(start)})
        for e in span.events:
            if e.kind in ("enqueue", "release"):
                continue
            evs.append({"name": name, "cat": "request", "ph": "n",
                        "id": rid, "pid": 2, "tid": 1, "ts": us(e.t),
                        "args": {"event": e.kind, **e.attrs}})
        evs.append({"name": name, "cat": "request", "ph": "e", "id": rid,
                    "pid": 2, "tid": 1, "ts": us(end)})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_chrome_trace(tel: Telemetry, path: str) -> None:
    """Write the Perfetto-loadable trace JSON (finite values enforced)."""
    with open(path, "w") as f:
        json.dump(chrome_trace(tel), f, allow_nan=False)


def summarize(tel: Telemetry) -> str:
    """Plain-text rollup: tick counts, decode tok/s, stall / TTFT / queue
    percentiles, pool watermarks, jit cache hit rates, and the roofline
    calibration table."""
    m = tel.metrics

    def pct(h, q):
        return m.histogram(h).percentile(q) * 1e3

    lines = ["telemetry summary:"]
    for kind in ("prefill", "chunk", "decode"):
        n = m.counter(f"ticks.{kind}").value
        if not n:
            continue
        h = m.histogram(f"tick.{kind}.measured_s")
        lines.append(f"  {kind:8} ticks={n:<6} measured p50="
                     f"{h.percentile(50) * 1e3:.2f}ms "
                     f"p99={h.percentile(99) * 1e3:.2f}ms")
    decode_s = m.histogram("tick.decode.measured_s").total
    decode_toks = m.counter("tokens.decode").value
    if decode_s > 0.0:
        lines.append(f"  decode tok/s (in-tick) = "
                     f"{decode_toks / decode_s:.1f}")
    if tel.stalls:
        lines.append(f"  stall p50={pct('stall.measured_s', 50):.2f}ms "
                     f"p99={pct('stall.measured_s', 99):.2f}ms "
                     f"(n={len(tel.stalls)})")
    ttft = tel.ttft_seconds()
    if ttft:
        mid = ttft[len(ttft) // 2]
        lines.append(f"  ttft p50={mid * 1e3:.1f}ms max={ttft[-1] * 1e3:.1f}"
                     f"ms (n={len(ttft)})")
    waits = tel.queue_wait_seconds()
    if waits:
        lines.append(f"  queue wait p50={waits[len(waits) // 2] * 1e3:.1f}ms "
                     f"max={waits[-1] * 1e3:.1f}ms")
    free = m.gauge("pool.free")
    if free.value is not None:
        lines.append(f"  pool free={free.value:.0f} low-water={free.min:.0f} "
                     f"preemptions={m.counter('preemptions').value}")
    occ = m.gauge("pool.occupancy")
    if occ.value is not None:
        frag = m.gauge("pool.fragmentation").value
        lines.append(f"  pool occupancy={occ.value:.2f} "
                     f"fragmentation={frag:.2f}")
    jit_bits = []
    for name in ("prefill", "pool_writer"):
        hits = m.gauge(f"jit.{name}.hits")
        if hits.value is not None:
            jit_bits.append(f"{name} {hits.value:.0f}h/"
                            f"{m.gauge(f'jit.{name}.misses').value:.0f}m")
    cache = m.gauge("jit.decode.cache_size")
    if cache.value is not None and cache.value >= 0:
        jit_bits.append(f"decode cache={cache.value:.0f}")
    if jit_bits:
        lines.append("  jit: " + "  ".join(jit_bits))
    if tel.ticks:
        lines.append(calibrate(tel.ticks).format())
    return "\n".join(lines)
