"""Telemetry sinks: where events go *beyond* the recorder's own ring.

The recorder (`recorder.Telemetry`) always keeps its in-memory record —
that is what the back-compat views, `calibrate()`, and the exporters
read. A sink is the streaming extension point on top: every tick event
and sequence edge is offered to it as it happens, so a live dashboard,
a log shipper, or a test can observe the engine without polling.

`NULL_SINK` is the default and the reason telemetry is free to leave
enabled: its methods are empty, so the disabled path costs one no-op
call per event and zero serialization.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.serving.telemetry.events import SeqEvent, TickEvent


class Sink:
    """Streaming consumer interface. Subclass and override what you need;
    the base class is deliberately a no-op so partial sinks stay cheap."""

    def tick(self, ev: TickEvent) -> None:
        pass

    def seq(self, rid: int, ev: SeqEvent) -> None:
        pass

    def close(self) -> None:
        pass


class NullSink(Sink):
    """The default: drop everything (inherits the no-op methods)."""


class RecordingSink(Sink):
    """Keep every offered event in order — for tests and ad-hoc scripts
    that want the stream itself rather than the recorder's structured
    ticks/spans."""

    def __init__(self):
        self.ticks: List[TickEvent] = []
        self.seq_events: List[Tuple[int, SeqEvent]] = []

    def tick(self, ev: TickEvent) -> None:
        self.ticks.append(ev)

    def seq(self, rid: int, ev: SeqEvent) -> None:
        self.seq_events.append((rid, ev))


NULL_SINK = NullSink()
