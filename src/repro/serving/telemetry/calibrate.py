"""Roofline calibration: fit predicted vs measured tick latency.

The paper's loop (HAQ/ProxylessNAS) only works because its fast feedback
signal — a latency table or roofline — is validated against the real
device. This module is that validation for the serving engine:
`calibrate()` takes the recorded tick events (each carrying
``predicted_s`` from `admission.step_latency` next to fenced wall-clock
``measured_s``) and fits, per (tick kind, padded batch, q_len) group,
the least-squares scale ``measured ≈ scale * predicted`` through the
origin, plus the median relative error.

The per-kind scale factors are exactly the correction
`core/hardware_model` would need for its roofline to predict this host
— the direct input for the ROADMAP's serving-stack autotuner, which
wants to search on the (cheap) roofline and trust it only as far as
this report says it deserves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.serving.telemetry.events import TickEvent


@dataclasses.dataclass
class CalibrationGroup:
    """Predicted-vs-measured fit for one (kind, batch, q_len) shape."""
    kind: str
    batch: int                 # padded jit batch (what actually runs)
    q_len: int
    n: int
    predicted_s: float         # the group's (constant) roofline prediction
    measured_p50_s: float
    measured_p99_s: float
    measured_mean_s: float
    scale: Optional[float]     # measured ~= scale * predicted (None: no pred)
    rel_err: Optional[float]   # median |measured - predicted| / predicted

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ScaleLookup:
    """Calibration scales as a queryable lookup — the report's export for
    consumers that price rooflines at arbitrary shapes (the serving-stack
    autotuner, `admission.RooflinePredictor(scales=...)`).

    Resolution order for ``scale(kind, batch, q_len)``:

      1. the exact (kind, batch, q_len) group the warmup trace measured;
      2. the kind's sample-weighted aggregate scale (the shape searched
         by the autotuner rarely matches a warmup shape exactly — the
         per-kind factor is the transferable signal);
      3. ``None`` — no calibration for this kind (e.g. the warmup engine
         ran an unknown ``hw_name``, so every prediction was 0.0 and
         `calibrate` fitted nothing). Callers must fall back to the raw
         roofline explicitly rather than multiplying by a made-up 1.0
         silently — see autotune/objective.py for the logged fallback.

    Only finite, positive fits are stored; ``from_dict`` round-trips the
    JSON shape written into serving-config files.
    """
    by_shape: Dict[Tuple[str, int, int], float] = \
        dataclasses.field(default_factory=dict)
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def scale(self, kind: str, batch: Optional[int] = None,
              q_len: Optional[int] = None) -> Optional[float]:
        if batch is not None and q_len is not None:
            got = self.by_shape.get((kind, int(batch), int(q_len)))
            if got is not None:
                return got
        return self.by_kind.get(kind)

    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted(self.by_kind))

    def as_dict(self) -> Dict:
        return {
            "by_kind": dict(self.by_kind),
            "by_shape": {f"{k}/{b}/{q}": s
                         for (k, b, q), s in sorted(self.by_shape.items())},
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ScaleLookup":
        by_shape = {}
        for key, s in (d.get("by_shape") or {}).items():
            kind, b, q = key.rsplit("/", 2)
            by_shape[(kind, int(b), int(q))] = float(s)
        return cls(by_shape=by_shape,
                   by_kind={k: float(v)
                            for k, v in (d.get("by_kind") or {}).items()})


@dataclasses.dataclass
class CalibrationReport:
    groups: List[CalibrationGroup]

    def scale_factors(self) -> Dict[str, Optional[float]]:
        """Per tick kind, the sample-weighted least-squares scale the
        roofline is off by on this host (measured = scale * predicted)."""
        out: Dict[str, Optional[float]] = {}
        for kind in sorted({g.kind for g in self.groups}):
            num = den = 0.0
            for g in self.groups:
                if g.kind != kind or g.scale is None:
                    continue
                # un-normalize the per-group fit back to sums of m*p, p*p
                den_g = g.n * g.predicted_s * g.predicted_s
                num += g.scale * den_g
                den += den_g
            out[kind] = (num / den) if den > 0.0 else None
        return out

    def rel_err_by_kind(self) -> Dict[str, Optional[float]]:
        """Per tick kind, the sample-weighted mean of group median
        relative errors — the single "how wrong is the roofline" number
        the bench records."""
        out: Dict[str, Optional[float]] = {}
        for kind in sorted({g.kind for g in self.groups}):
            num = den = 0
            for g in self.groups:
                if g.kind != kind or g.rel_err is None:
                    continue
                num += g.rel_err * g.n
                den += g.n
            out[kind] = (num / den) if den else None
        return out

    def scale_lookup(self) -> ScaleLookup:
        """Export the fits as a `ScaleLookup` (exact-shape scales plus the
        per-kind aggregates). Groups with no prediction (scale None) are
        dropped — the lookup answers None for them and the caller decides
        how to fall back."""
        by_shape = {
            (g.kind, g.batch, g.q_len): float(g.scale)
            for g in self.groups
            if g.scale is not None and g.scale > 0.0
        }
        by_kind = {k: float(s) for k, s in self.scale_factors().items()
                   if s is not None and s > 0.0}
        return ScaleLookup(by_shape=by_shape, by_kind=by_kind)

    def as_dict(self) -> Dict:
        return {
            "groups": [g.as_dict() for g in self.groups],
            "scale": self.scale_factors(),
            "rel_err": self.rel_err_by_kind(),
        }

    def format(self) -> str:
        """Human-readable table for launch/serve.py and bench logs."""
        lines = ["roofline calibration (measured = scale * predicted):",
                 f"{'kind':8} {'batch':>5} {'q_len':>5} {'n':>5} "
                 f"{'pred_ms':>9} {'p50_ms':>9} {'scale':>7} {'relerr':>7}"]
        for g in sorted(self.groups, key=lambda g: (g.kind, g.batch,
                                                    g.q_len)):
            scale = "-" if g.scale is None else f"{g.scale:.2f}"
            rel = "-" if g.rel_err is None else f"{g.rel_err:.2f}"
            lines.append(
                f"{g.kind:8} {g.batch:>5} {g.q_len:>5} {g.n:>5} "
                f"{g.predicted_s * 1e3:>9.3f} "
                f"{g.measured_p50_s * 1e3:>9.3f} {scale:>7} {rel:>7}")
        for kind, scale in self.scale_factors().items():
            if scale is not None:
                lines.append(f"  -> hardware_model scale[{kind}] = "
                             f"{scale:.3f}")
        return "\n".join(lines)


def calibrate(ticks: Iterable[TickEvent]) -> CalibrationReport:
    """Group tick events by (kind, padded_batch, q_len) and fit each
    group's predicted-vs-measured latency. Groups whose prediction is
    absent (unknown hardware target => predicted_s == 0) still report
    measured percentiles with ``scale``/``rel_err`` of None."""
    by_key: Dict[Tuple[str, int, int], List[TickEvent]] = {}
    for ev in ticks:
        by_key.setdefault((ev.kind, ev.padded_batch, ev.q_len),
                          []).append(ev)
    groups = []
    for (kind, batch, q_len), evs in sorted(by_key.items()):
        m = np.asarray([e.measured_s for e in evs], np.float64)
        p = np.asarray([e.predicted_s for e in evs], np.float64)
        pred = float(p.mean())
        if pred > 0.0:
            scale = float((m * p).sum() / (p * p).sum())
            rel_err = float(np.median(np.abs(m - p) / p))
        else:
            scale = rel_err = None
        groups.append(CalibrationGroup(
            kind=kind, batch=batch, q_len=q_len, n=len(evs),
            predicted_s=pred,
            measured_p50_s=float(np.percentile(m, 50)),
            measured_p99_s=float(np.percentile(m, 99)),
            measured_mean_s=float(m.mean()),
            scale=scale, rel_err=rel_err))
    return CalibrationReport(groups=groups)
