"""The telemetry recorder the engine (and scheduler) write into.

One `Telemetry` instance per engine: tick events, sequence spans, stall
records, and the metrics registry live here; a `Sink` (NULL_SINK by
default) additionally sees every event as it happens. The recorder is
jax-free and clock-injectable, so scheduler tests and synthetic
calibration fixtures run without a device or real time.

The monotonic trace clock (`t0`) starts at the engine's first step (or
first recorded event) and resets with `reset()`, matching the engine's
pre-telemetry behaviour where benchmarks re-time a warmed instance:
warm run -> `Engine.reset_stats()` -> timed run re-stamps everything
relative to the timed run's start.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

from repro.serving.telemetry.events import (SeqEvent, SeqSpan, StallRecord,
                                            TickEvent)
from repro.serving.telemetry.metrics import MetricsRegistry
from repro.serving.telemetry.sinks import NULL_SINK, Sink


class Telemetry:
    def __init__(self, sink: Optional[Sink] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.sink = sink if sink is not None else NULL_SINK
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.clock = clock
        self.ticks: List[TickEvent] = []
        self.spans: Dict[int, SeqSpan] = {}
        self.stalls: List[StallRecord] = []
        self.t0: Optional[float] = None

    # -------------------------------------------------------------- clock --
    def start_clock(self) -> float:
        """Start (idempotently) the trace clock; returns t0."""
        if self.t0 is None:
            self.t0 = self.clock()
        return self.t0

    def now(self) -> float:
        return self.clock()

    def rel(self, t: Optional[float]) -> Optional[float]:
        """Absolute monotonic -> seconds since the trace clock started."""
        if t is None:
            return None
        return t - (self.t0 if self.t0 is not None else t)

    # ------------------------------------------------------------- emitters --
    def tick(self, ev: TickEvent) -> None:
        """Record one tick event and roll it into the metrics registry."""
        self.ticks.append(ev)
        m = self.metrics
        m.counter(f"ticks.{ev.kind}").inc()
        m.counter(f"tokens.{ev.kind}").inc(ev.tokens)
        if ev.preempted:
            m.counter("preemptions").inc(ev.preempted)
        m.gauge("pool.free").set(ev.pool_free)        # .min = low-water mark
        m.gauge("pool.allocated").set(ev.pool_allocated)
        m.gauge("queue.depth").set(ev.queue_depth)
        m.histogram(f"tick.{ev.kind}.measured_s").observe(ev.measured_s)
        if ev.predicted_s > 0.0:
            m.histogram(f"tick.{ev.kind}.rel_err").observe(ev.rel_err)
        self.sink.tick(ev)

    def seq_event(self, rid: int, kind: str, **attrs) -> SeqEvent:
        """Append one lifecycle edge to ``rid``'s span."""
        span = self.spans.get(rid)
        if span is None:
            span = self.spans[rid] = SeqSpan(rid)
        ev = SeqEvent(kind=kind, t=self.clock(), attrs=attrs)
        span.events.append(ev)
        self.sink.seq(rid, ev)
        return ev

    def stall(self, measured_s: float, predicted_s: float) -> None:
        """Record one decode tick's prefill stall (measured + predicted)."""
        self.stalls.append(StallRecord(measured_s, predicted_s))
        self.metrics.histogram("stall.measured_s").observe(measured_s)

    # ---------------------------------------------------------------- views --
    def stall_log_view(self) -> List[float]:
        """Measured per-decode-tick stall seconds — the exact list
        ``Engine.stall_log`` exposed before telemetry existed."""
        return [r.measured_s for r in self.stalls]

    def first_token_view(self) -> Dict[int, float]:
        """rid -> time-to-first-token seconds relative to the trace clock
        (first ``first_token`` edge only: a preempted request's re-served
        extension never moves its TTFT) — the ``Engine.first_token_s``
        back-compat view."""
        out = {}
        for rid, span in self.spans.items():
            t = span.first_token_t
            if t is not None:
                out[rid] = self.rel(t)
        return out

    def ttft_seconds(self) -> List[float]:
        return sorted(self.first_token_view().values())

    def queue_wait_seconds(self) -> List[float]:
        out = []
        for span in self.spans.values():
            w = span.queue_wait_s()
            if w is not None:
                out.append(w)
        return sorted(out)

    # ---------------------------------------------------------------- admin --
    def reset(self) -> None:
        """Drop all recorded state and restart the trace clock on the
        next event (Engine.reset_stats delegates here)."""
        self.ticks.clear()
        self.spans.clear()
        self.stalls.clear()
        self.metrics.reset()
        self.t0 = None

    def close(self) -> None:
        self.sink.close()
