"""Typed telemetry events: per-tick traces and per-sequence lifecycle
spans.

A **tick event** is one jitted engine dispatch — a whole-prompt prefill,
one prompt chunk, or one batched decode step — carrying the measured
wall-clock duration (fenced: the engine blocks on the dispatch's outputs
before stopping the timer, so async jit dispatch is never mistaken for
compute) *next to* the roofline-predicted duration for the same shape.
That pairing is the point of the layer: `telemetry.calibrate` fits the
two against each other per (kind, batch, q_len) and reports how far the
`core/hardware_model` roofline — the fast feedback signal of every
search loop in this repo — is from the machine it runs on.

A **sequence span** is the lifecycle of one request: enqueue -> admit ->
chunk* -> first_token -> (preempt -> requeue -> admit -> ...)* ->
finish/release. Spans yield the real time-to-first-token, queue wait,
and preemption history that `Engine.first_token_s` / the stall log used
to approximate with bare lists (both survive as thin views).

Everything here is host-side plain Python (dataclasses + floats): no
jax, so the scheduler and tests stay importable without a device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

TICK_KINDS = ("prefill", "chunk", "decode")

# sequence-span edge kinds, in lifecycle order (preempt/requeue may cycle)
SEQ_EVENTS = ("enqueue", "admit", "chunk", "first_token", "preempt",
              "requeue", "finish", "release")


@dataclasses.dataclass
class TickEvent:
    """One jitted engine dispatch, measured and predicted side by side.

    ``measured_s`` is wall clock around the dispatch *including* the
    fence (``block_until_ready`` / the host transfer of its outputs);
    ``predicted_s`` is ``admission.step_latency`` for the same (kind,
    padded_batch, q_len) — 0.0 when the policy's hardware target is
    unknown (hand-built test policies). ``batch`` is the live sequence
    count; ``padded_batch`` is the fixed jit batch that actually runs
    (idle slots ride along), which is why predictions use it.

    Page deltas are since the *previous* tick event, so admission-time
    allocations land on the step's first event and growth/trim/preempt
    frees land on the decode event that caused them.
    """
    kind: str                 # "prefill" | "chunk" | "decode"
    step: int                 # engine step() index
    t_start: float            # absolute monotonic seconds
    measured_s: float
    predicted_s: float
    batch: int                # live sequences in this dispatch
    padded_batch: int         # fixed jit batch (idle slots ride along)
    q_len: int                # query rows per sequence (1 for decode)
    tokens: int               # tokens produced / prompt tokens advanced
    rids: Tuple[int, ...] = ()
    admitted: int = 0         # admissions so far this step
    preempted: int = 0        # preemptions caused by this dispatch
    pages_allocated: int = 0  # page deltas since the previous tick event
    pages_freed: int = 0
    pages_trimmed: int = 0
    queue_depth: int = 0      # scheduler queue at emit time
    pool_free: int = 0        # free pages at emit time
    pool_allocated: int = 0   # allocated pages at emit time
    tags: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def rel_err(self) -> float:
        """|measured - predicted| / predicted (0.0 when unpredicted)."""
        if self.predicted_s <= 0.0:
            return 0.0
        return abs(self.measured_s - self.predicted_s) / self.predicted_s


@dataclasses.dataclass
class SeqEvent:
    """One edge of a sequence's lifecycle span."""
    kind: str                 # one of SEQ_EVENTS
    t: float                  # absolute monotonic seconds
    attrs: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SeqSpan:
    """All lifecycle edges of one request id, in emission order.

    A preempted request cycles admit -> preempt -> requeue -> admit; its
    derived timestamps always take the FIRST matching edge (a request's
    TTFT is when its first token was *served*, not re-computed)."""
    rid: int
    events: List[SeqEvent] = dataclasses.field(default_factory=list)

    def first(self, kind: str):
        for ev in self.events:
            if ev.kind == kind:
                return ev
        return None

    def count(self, kind: str) -> int:
        return sum(1 for ev in self.events if ev.kind == kind)

    @property
    def enqueue_t(self):
        ev = self.first("enqueue")
        return None if ev is None else ev.t

    @property
    def admit_t(self):
        ev = self.first("admit")
        return None if ev is None else ev.t

    @property
    def first_token_t(self):
        ev = self.first("first_token")
        return None if ev is None else ev.t

    @property
    def finish_t(self):
        ev = self.first("finish")
        return None if ev is None else ev.t

    def queue_wait_s(self):
        """Seconds from enqueue to first admission (None if unadmitted)."""
        if self.enqueue_t is None or self.admit_t is None:
            return None
        return self.admit_t - self.enqueue_t


@dataclasses.dataclass
class StallRecord:
    """Per-decode-tick prefill stall: the seconds this tick's already-
    ready sequences *measurably* waited on prefill work that step, next
    to the roofline's prediction for the same chunks — the quantity
    ``prefill_stall_factor`` budgets, now with both sides recorded
    (``Engine.stall_log`` is the measured-only back-compat view)."""
    measured_s: float
    predicted_s: float
