"""Metrics registry: counters, gauges (with min/max watermarks), and
histograms, host-side and jax-free.

The registry is always "on" — its instruments are plain Python ints and
float lists, cheap enough that the engine updates them unconditionally —
while *export* cost lives entirely in the sinks (`sinks.NULL_SINK` by
default, so a disabled engine pays no serialization). Instruments are
created on first use and survive `reset()` with zeroed state, so a
steady-state monitor can hold references across bench re-timings.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional


class Counter:
    """Monotonic event count (resettable between bench timings)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-set value plus min/max watermarks since the last reset —
    the min watermark is how the free-page low-water mark is kept
    without storing a sample per tick."""

    __slots__ = ("value", "min", "max")

    def __init__(self):
        self.value: Optional[float] = None
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def set(self, v) -> None:
        v = float(v)
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def reset(self) -> None:
        self.value = self.min = self.max = None


class Histogram:
    """Sample store with percentile queries. Samples are kept raw (the
    engine's tick counts are bench-scale, thousands not billions); a
    ``maxlen`` bound drops the oldest half when exceeded so a long-lived
    engine cannot grow without limit."""

    __slots__ = ("samples", "count", "total", "maxlen")

    def __init__(self, maxlen: int = 1 << 16):
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.maxlen = maxlen

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.samples.append(v)
        if len(self.samples) > self.maxlen:
            del self.samples[:len(self.samples) // 2]

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over retained samples (0.0 if empty)."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        rank = max(math.ceil(q / 100.0 * len(xs)) - 1, 0)
        return xs[min(rank, len(xs) - 1)]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def reset(self) -> None:
        self.samples.clear()
        self.count = 0
        self.total = 0.0


class MetricsRegistry:
    """Name -> instrument maps with create-on-first-use accessors.

    Naming convention (dotted, grep-able): ``ticks.decode``,
    ``tokens.decode``, ``preemptions``, ``pool.free`` (min = low-water
    mark), ``pool.occupancy``, ``pool.fragmentation``, ``queue.depth``,
    ``jit.prefill.hits`` / ``.misses``, ``jit.pool_writer.hits`` /
    ``.misses``, ``jit.decode.cache_size``, ``tick.decode.measured_s``
    (histogram), ``tick.decode.rel_err`` (histogram), and the chunk /
    prefill twins of the tick instruments."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def as_dict(self) -> Dict[str, Dict]:
        """JSON-ready snapshot (histograms as count/mean/p50/p99)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: {"value": g.value, "min": g.min, "max": g.max}
                       for k, g in sorted(self.gauges.items())},
            "histograms": {k: {"count": h.count, "mean": h.mean,
                               "p50": h.percentile(50),
                               "p99": h.percentile(99)}
                           for k, h in sorted(self.histograms.items())},
        }

    def reset(self) -> None:
        """Zero every instrument in place (references stay valid)."""
        for c in self.counters.values():
            c.reset()
        for g in self.gauges.values():
            g.reset()
        for h in self.histograms.values():
            h.reset()
