"""Hardware-aware admission policy, derived from the TPU roofline simulator.

`derive_policy` answers, per hardware target, the questions the scheduler
must not answer by guessing:

  * ``num_pages``   — how much KV the target's HBM holds after weights
                      (the memory roofline; paper Fig. 4's y-intercept)
  * ``max_batch``   — largest in-flight batch whose decode step still meets
                      the latency SLO (decode is memory-bound on the edge
                      chip, compute/collective-bound on pod slices)
  * ``prefill_chunk`` — prompt chunk per engine tick: the largest chunk
                      whose prefill-with-cache forward keeps the
                      *per-tick* decode stall within the stall budget
                      (``prefill_stall_factor`` SLOs) — long prompts cost
                      more ticks, never a bigger stall. Whole-prompt mode
                      reuses it as the padding-bucket quantum.
  * ``quant_bits``  — 16 (bf16) unless weights + one sequence of KV exceed
                      the HBM budget, in which case the HAQ default bit
                      policy (serving/quant.py) is applied: 8, then 4
  * ``kv_bits``     — stored KV-cache bits for the page pool
                      (serving/kvquant): every sizing quantity above is
                      priced at the quantized width, so an int8 pool holds
                      ~2x the pages and admits ~2x the resident sequences
                      in the same HBM

All quantities come from `core/hardware_model.py` OpCosts — the same
roofline that drives NAS/AMC/HAQ at search time, now queried at serve time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import hardware_model as hwm


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    hw_name: str
    max_model_len: int
    page_size: int
    num_pages: int          # pages the target's HBM can hold (incl. scratch)
    max_batch: int          # max in-flight sequences
    prefill_chunk: int      # prompt chunk per tick / padding quantum
    quant_bits: int         # 16 = bf16 weights; 8/4 = HAQ default bits
    decode_slo_s: float
    est_decode_s: float     # roofline decode-step latency at max_batch
    est_prefill_s: float    # roofline per-chunk (per-tick) prefill latency
    # stored KV-cache bits per sub-layer slot (serving/kvquant); None = bf16
    # pool. Cycled over layers like attn_pattern.
    kv_bits: Optional[Tuple[int, ...]] = None
    # serving mesh the policy was sized for (engine/sharded.py): the pool
    # shards kv_heads over `mesh_model` devices (per-device page bytes drop
    # ~Nx, so num_pages rises ~Nx in the same per-device HBM) and params
    # spread at rest over all mesh_model*mesh_data devices. 1/1 = the
    # single-device engine.
    mesh_model: int = 1
    mesh_data: int = 1

    @property
    def pages_per_seq(self) -> int:
        return -(-self.max_model_len // self.page_size)


def _kv_bits_for_layer(kv_bits, i: int) -> int:
    if kv_bits is None:
        return 16
    if isinstance(kv_bits, int):
        return kv_bits
    return kv_bits[i % len(kv_bits)]


def kv_bytes_per_token(cfg, kv_bits=None) -> int:
    """k+v bytes per cached token across all layers, at the pool's stored
    precision: bf16 by default; with a KV bit policy (int or per-sub-layer
    tuple, cycled like ``attn_pattern``) quantized slots store
    ``bits``-wide codes plus an fp32 scale per token per kv head for k and
    v each (serving/kvquant page layout). This is what sizes pages — so the
    whole admission roofline (pool capacity, expected-footprint batch,
    page bytes) is bit-policy-aware."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    total = 0
    for i in range(cfg.num_layers):
        b = _kv_bits_for_layer(kv_bits, i)
        per = 2 * K * (hd * b // 8)
        if b < 16:
            per += 2 * K * 4                 # fp32 scale tiles
        total += per
    return total


def _ffn_terms(cfg, i: int, tokens: int, hw, tp: int, w_bits):
    """FFN latency split into the part the sharded engine partitions over
    the model axis (up/gate projections — output-dim sharded) and the part
    that runs WHOLE on every device (the down-projection; the entire
    expert bank for MoE, whose weights are gathered at use), plus the
    at-rest weight bytes that gather costs. Their sum reproduces the
    single-device FFN latency exactly."""
    if cfg.is_moe_layer(i):
        m = cfg.moe
        mc = hwm.moe_cost(tokens, cfg.d_model, m.d_ff_expert,
                          m.num_experts, m.experts_per_token)
        return 0.0, float(mc.latency(hw, w_bits=w_bits)), \
            float(mc.weight_bytes) * w_bits / 16.0
    lin = hwm.linear_cost(tokens, cfg.d_model, cfg.d_ff, tp=tp)
    lat = float(lin.latency(hw, w_bits=w_bits))
    return 2.0 * lat, lat, float(lin.weight_bytes) * w_bits / 16.0


def step_latency(cfg, batch: int, q_len: int, ctx: int, hw: hwm.Hardware,
                 *, w_bits: int = 16, kv_bits=None,
                 mesh_model: int = 1) -> float:
    """Roofline latency of one forward step (q_len=1 -> decode tick).

    ``kv_bits`` (int or per-sub-layer tuple) prices the KV-cache reads at
    the pool's stored precision — the direct hardware feedback the kvquant
    HAQ search optimizes against. It applies to decode only: prefill
    attends its own fp activations before the pool write quantizes them.

    ``mesh_model`` prices the sharded engine FAITHFULLY to what
    engine/sharded.py runs per device: only the output-dim-sharded work
    splits N ways (q/k/v projections, the paged-attention walk — the
    decode-dominant KV reads — and the FFN up/gate projections); the
    contraction matmuls it refuses to psum-split for bit-exactness (attn
    out-projection, FFN down-projection, the MoE expert bank, unembed)
    run WHOLE on every device, and each layer additionally pays two
    residual-sized activation collectives (``hwm.allreduce_cost``) plus
    the ring all-gather of its at-rest-sharded weights
    (``hwm.gather_cost`` — the dominant ICI term for decode, which is why
    gather-based exact TP trades latency for capacity)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    tp = min(hw.chips, 16)
    shards = max(int(mesh_model), 1)
    tokens = batch * q_len
    decode = q_len == 1
    t = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.attn_pattern[i % len(cfg.attn_pattern)]
        window = cfg.window_size if kind == "local" else 0
        split = float(hwm.linear_cost(tokens, d, (H + 2 * K) * hd, tp=tp)
                      .latency(hw, w_bits=w_bits))
        split += float(hwm.attention_cost(
            batch, q_len, ctx, H, K, hd, window=window, decode=decode,
            kv_bits=_kv_bits_for_layer(kv_bits, i) if decode else 16)
            .latency(hw))
        out_proj = hwm.linear_cost(tokens, H * hd, d, tp=tp)
        whole = float(out_proj.latency(hw, w_bits=w_bits))
        f_split, f_whole, f_gather = _ffn_terms(cfg, i, tokens, hw, tp,
                                                w_bits)
        split += f_split
        whole += f_whole
        t += split / shards + whole
        if shards > 1:
            t += 2.0 * float(hwm.allreduce_cost(tokens, d, shards)
                             .latency(hw))
            gather = float(out_proj.weight_bytes) * w_bits / 16.0 + f_gather
            t += float(hwm.gather_cost(gather, shards).latency(hw))
    unembed = hwm.linear_cost(tokens, d, cfg.padded_vocab, tp=tp)
    t += float(unembed.latency(hw, w_bits=w_bits))
    if shards > 1:
        t += float(hwm.gather_cost(
            float(unembed.weight_bytes) * w_bits / 16.0, shards)
            .latency(hw))
    return t


class RooflinePredictor:
    """Memoized per-(kind, batch, q_len) roofline tick predictions for the
    telemetry layer (serving/telemetry): every engine tick event carries
    the `step_latency` prediction for its exact dispatch shape next to
    the measured wall clock, and `telemetry.calibrate` fits the two.

    Predictions price what the jit actually runs — the *padded* batch
    (idle decode slots ride along) at worst-case resident context, with
    the policy's weight bits, KV bit policy (decode only, matching
    `step_latency`), and mesh split. The memo makes the per-tick cost a
    dict lookup: decode always hits one key, chunk prefill one more, and
    whole-prompt prefill one per padding bucket.

    Hand-built policies (tests) may name a hardware target that is not in
    ``HARDWARES``; prediction is then 0.0 — "no prediction" — which
    calibration and the Chrome trace both represent explicitly rather
    than inventing a number.

    ``scales`` (a `telemetry.calibrate.ScaleLookup`, or anything with its
    ``scale(kind, batch, q_len) -> Optional[float]`` shape) turns the raw
    roofline into the host-corrected prediction the autotuner searches
    on: the memoized analytic latency is multiplied by the fitted
    measured/predicted factor for the dispatch shape (exact shape first,
    then the kind's aggregate). A kind the warmup never measured resolves
    to None and the raw roofline passes through unscaled — never zeroed."""

    def __init__(self, cfg, policy: AdmissionPolicy, scales=None):
        self.cfg = cfg
        self.policy = policy
        self.scales = scales
        self.hw = hwm.HARDWARES.get(policy.hw_name)
        self._memo: dict = {}

    def raw(self, kind: str, batch: int, q_len: int) -> float:
        """The uncalibrated analytic roofline for one dispatch shape
        (0.0 = no prediction for an unknown hardware target)."""
        key = (kind, batch, q_len)
        got = self._memo.get(key)
        if got is None:
            p = self.policy
            if self.hw is None:
                got = 0.0
            else:
                got = float(step_latency(
                    self.cfg, batch, q_len, p.max_model_len, self.hw,
                    w_bits=p.quant_bits, kv_bits=p.kv_bits,
                    mesh_model=p.mesh_model))
            self._memo[key] = got
        return got

    def __call__(self, kind: str, batch: int, q_len: int) -> float:
        got = self.raw(kind, batch, q_len)
        if self.scales is not None and got > 0.0:
            s = self.scales.scale(kind, batch, q_len)
            if s is not None:
                got *= s
        return got


def derive_policy(cfg, hw: hwm.Hardware, *, max_model_len: int,
                  page_size: int = 16, decode_slo_s: float = 0.030,
                  prefill_stall_factor: float = 4.0,
                  hbm_util: float = 0.9,
                  max_batch_cap: int = 1024,
                  expected_occupancy: float = 0.5,
                  param_bytes: Optional[int] = None,
                  kv_bits=None, mesh_model: int = 1,
                  mesh_data: int = 1) -> AdmissionPolicy:
    """Pick (num_pages, max_batch, prefill_chunk, quant_bits) for a target.

    ``param_bytes`` defaults to the analytic bf16 weight footprint
    (``cfg.param_count() * 2``); pass the exact value from
    ``Model.param_bytes()`` when available.

    ``expected_occupancy`` sizes the memory-bound batch from the *expected*
    per-sequence KV footprint (that fraction of ``max_model_len``) rather
    than the worst case: pages are allocated lazily and the engine preempts
    on exhaustion, so admission no longer has to reserve for every
    sequence simultaneously hitting max length. 1.0 restores the
    worst-case sizing that matches ``reserve_upfront`` scheduling.

    ``kv_bits`` (already normalized: None, int, or per-sub-layer tuple —
    see models/transformer.py::normalize_kv_bits and serving/kvquant)
    shrinks per-token KV bytes, so the same HBM budget holds 2-4x the
    pages and the expected-footprint batch grows with it; the decode-SLO
    search prices KV reads at the quantized width.

    ``mesh_model``/``mesh_data`` size for the SPMD engine (one hw target
    per mesh device): the whole roofline is priced **per shard**. Params
    live at rest spread across all ``mesh_model * mesh_data`` devices, and
    the pool's kv-head split divides per-device page bytes by
    ``mesh_model`` — so pool capacity (``num_pages``, and with it the
    expected-footprint resident-sequence count) rises ~Nx along the model
    axis while the decode-SLO search pays the per-layer all-reduce term
    (``step_latency(mesh_model=)``). 1/1 reproduces the single-device
    policy exactly.
    """
    if not 0.0 < expected_occupancy <= 1.0:
        raise ValueError(f"expected_occupancy must be in (0, 1], "
                         f"got {expected_occupancy}")
    if mesh_model < 1 or mesh_data < 1:
        raise ValueError(f"mesh axes must be >= 1, got "
                         f"model={mesh_model} data={mesh_data}")
    if cfg.is_encdec or cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"admission policy sizes attention KV pools; {cfg.name} "
            f"(family={cfg.family!r}) is an open item (ROADMAP)")
    if param_bytes is None:
        param_bytes = cfg.param_count() * 2
    devices = mesh_model * mesh_data
    # per-shard HBM: each mesh device is one hw target; params at rest are
    # spread across every device (TP dims local + FSDP over data), the pool
    # replicates over data and splits kv_heads over model.
    hbm_total = hw.hbm_bytes * hw.chips * hbm_util
    per_tok = kv_bytes_per_token(cfg, kv_bits)
    one_seq_kv = per_tok * max_model_len / mesh_model

    # HAQ escalation: shrink weights until weights + one sequence fit.
    quant_bits = 16
    for bits in (16, 8, 4):
        if param_bytes * bits / 16.0 / devices + one_seq_kv <= hbm_total:
            quant_bits = bits
            break
    else:
        raise ValueError(
            f"{cfg.name} cannot fit on {hw.name} x{devices}: weights at "
            f"4-bit plus one {max_model_len}-token sequence exceed "
            f"{hbm_total / 2**30:.1f} GiB per device")

    kv_budget = hbm_total - param_bytes * quant_bits / 16.0 / devices
    page_bytes = page_size * per_tok / mesh_model   # per-shard page slice
    pages_per_seq = -(-max_model_len // page_size)
    # floor at one full sequence: the quant check above guarantees weights +
    # one_seq_kv fit, but page-granular rounding could otherwise leave the
    # pool a partial page short of a max-length request, which the scheduler
    # would wait on forever. Overshoot is < 2 pages (incl. scratch page 0).
    num_pages = max(int(kv_budget // page_bytes), pages_per_seq) + 1
    # expected (not worst-case) footprint: lazy page growth + preemption
    # absorb the tail where every sequence runs to max_model_len at once.
    pages_expected = max(
        -(-int(expected_occupancy * max_model_len) // page_size), 1)
    mem_batch = max((num_pages - 1) // pages_expected, 1)

    # Decode-latency roofline: largest batch meeting the SLO (monotonic).
    lo, hi = 1, max(min(mem_batch, max_batch_cap), 1)
    if step_latency(cfg, hi, 1, max_model_len, hw, w_bits=quant_bits,
                    kv_bits=kv_bits, mesh_model=mesh_model) <= decode_slo_s:
        max_batch = hi
    else:
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if step_latency(cfg, mid, 1, max_model_len, hw,
                            w_bits=quant_bits, kv_bits=kv_bits,
                            mesh_model=mesh_model) <= decode_slo_s:
                lo = mid
            else:
                hi = mid
        max_batch = lo
    est_decode = step_latency(cfg, max_batch, 1, max_model_len, hw,
                              w_bits=quant_bits, kv_bits=kv_bits,
                              mesh_model=mesh_model)

    # Prefill chunk: largest power-of-two chunk whose prefill-with-cache
    # forward — priced at the worst-case resident context, since a late
    # chunk of a long prompt attends the whole prefix in the pool — fits
    # the stall budget. The engine runs one chunk per tick per sequence,
    # so prefill_stall_factor bounds the *per-tick* decode stall directly:
    # long prompts cost more ticks, never a bigger bucket.
    stall_budget = prefill_stall_factor * decode_slo_s
    chunk = 16
    c = 16
    while c * 2 <= max_model_len:
        c *= 2
        if step_latency(cfg, 1, c, max_model_len, hw, w_bits=quant_bits,
                        mesh_model=mesh_model) > stall_budget:
            break
        chunk = c
    est_prefill = step_latency(cfg, 1, chunk, max_model_len, hw,
                               w_bits=quant_bits, mesh_model=mesh_model)

    if kv_bits is not None and isinstance(kv_bits, int):
        kv_bits = (kv_bits,)
    return AdmissionPolicy(
        hw_name=hw.name, max_model_len=max_model_len, page_size=page_size,
        num_pages=num_pages, max_batch=max_batch, prefill_chunk=chunk,
        quant_bits=quant_bits, decode_slo_s=decode_slo_s,
        est_decode_s=est_decode, est_prefill_s=est_prefill,
        kv_bits=kv_bits, mesh_model=mesh_model, mesh_data=mesh_data)
