"""Paged KV-cache pool: host-side page allocator + device-side pool arrays.

The allocator is plain Python (a free list) — allocation decisions are
control flow, not compute, and stay off the device. The device pool is the
pytree from ``Model.pool_specs``; page 0 is reserved as scratch: idle batch
slots and unused page-table tails write/gather there, so scatters never need
masking inside the jitted decode step.
"""
from __future__ import annotations

import contextlib
import warnings
from collections import deque
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@contextlib.contextmanager
def quiet_donation():
    """Silence JAX's unused-donation warning around the engine's own donated
    dispatches only: CPU ignores buffer donation, and process-wide filtering
    would hide genuine missed-donation regressions elsewhere."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages (page 0 is the
    scratch page and is never handed out)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: deque = deque(range(1, num_pages))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve n pages, or None if the pool can't satisfy the request."""
        if n > len(self._free):
            return None
        return [self._free.popleft() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"freeing invalid page {p}")
        self._free.extend(pages)


class PagedKVPool:
    """Device pool arrays + the allocator that tracks their occupancy."""

    def __init__(self, model, num_pages: int, page_size: int):
        self.allocator = PageAllocator(num_pages, page_size)
        self.page_size = page_size
        self.pool = model.init_pool(num_pages, page_size)
        self._write_jit = {}        # (n_pages, cache_len) -> jitted writer

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    def write_prefill(self, cache, pages: Sequence[int]) -> None:
        """Scatter one request's prefill cache (full layout, B=1, bucket-
        padded length) into its pages. Jitted per (n_pages, cache_len) shape
        with the pool donated, so the write is an in-place scatter rather
        than a full-pool copy per admission. Bucket-padding garbage beyond
        the true prompt lands only inside the request's own pages and is
        masked (j <= pos) or overwritten by decode."""
        n = len(pages)
        page = self.page_size
        Sp = jax.tree.leaves(cache)[0].shape[2]
        span = n * page

        key = (n, Sp)
        fn = self._write_jit.get(key)
        if fn is None:
            def write(pool, cache, idx):
                def wr(pool_leaf, cache_leaf):
                    c = cache_leaf[:, 0]                # (G, Sp, K, hd)
                    if Sp >= span:
                        c = c[:, :span]
                    else:
                        c = jnp.pad(c, ((0, 0), (0, span - Sp))
                                    + ((0, 0),) * (c.ndim - 2))
                    c = c.reshape(c.shape[0], n, page, *c.shape[2:])
                    return pool_leaf.at[:, idx].set(c)
                return jax.tree.map(wr, pool, cache)
            fn = jax.jit(write, donate_argnums=(0,))
            self._write_jit[key] = fn

        with quiet_donation():
            self.pool = fn(self.pool, cache,
                           jnp.asarray(np.asarray(pages, np.int32)))
