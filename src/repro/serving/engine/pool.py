"""Paged KV-cache pool: host-side page allocator + device-side pool arrays.

The allocator is plain Python (a free list) — allocation decisions are
control flow, not compute, and stay off the device. The device pool is the
pytree from ``Model.pool_specs``; page 0 is reserved as scratch: idle batch
slots and unused page-table tails write/gather there, so scatters never need
masking inside the jitted decode step.
"""
from __future__ import annotations

import contextlib
import warnings
from collections import OrderedDict, deque
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@contextlib.contextmanager
def quiet_donation():
    """Silence JAX's unused-donation warning around the engine's own donated
    dispatches only: CPU ignores buffer donation, and process-wide filtering
    would hide genuine missed-donation regressions elsewhere."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages (page 0 is the
    scratch page and is never handed out).

    Tracks the allocated set so a double-free is rejected instead of
    silently entering the free list twice — a page freed twice would be
    handed to two sequences, which corrupts both KV streams."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: deque = deque(range(1, num_pages))
        self._allocated: set = set()
        # lifetime telemetry counters (serving/telemetry): tick events
        # report alloc/free *deltas* by differencing these, and min_free
        # is the free-page low-water mark — how close the pool came to
        # preemption pressure.
        self.total_allocated = 0
        self.total_freed = 0
        self.min_free = len(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve n pages, or None if the pool can't satisfy the request."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._allocated.update(pages)
        self.total_allocated += n
        self.min_free = min(self.min_free, len(self._free))
        return pages

    def free(self, pages: Sequence[int]) -> None:
        seen = set()
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"freeing invalid page {p}")
            if p not in self._allocated or p in seen:
                raise ValueError(f"double free of page {p}")
            seen.add(p)
        self._allocated.difference_update(seen)
        self._free.extend(pages)
        self.total_freed += len(seen)


class JitLRU:
    """Bounded per-shape jit cache: each entry is its own ``jax.jit``
    instance keyed by a shape tuple, so evicting the entry really drops the
    compiled executable. Long-running engines see an open-ended set of
    bucket shapes (prefill buckets, prefill-span writers); without a cap the
    retrace caches grow without limit."""

    def __init__(self, cap: int = 8):
        self.cap = cap
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key, make: Callable):
        fn = self._d.get(key)
        if fn is None:
            self.misses += 1
            fn = make()
            self._d[key] = fn
            while len(self._d) > self.cap:
                self._d.popitem(last=False)
        else:
            self.hits += 1
            self._d.move_to_end(key)
        return fn


class PagedKVPool:
    """Device pool arrays + the allocator that tracks their occupancy.

    ``kv_bits`` (already normalized — see transformer.normalize_kv_bits)
    selects the HAQ KV-quantized pool layout per sub-layer slot
    (serving/kvquant): quantized slots store int8/int4 codes plus
    per-page-slot per-head fp32 scale tiles, and the prefill writer
    quantizes on write with the same mapping the decode scatter uses."""

    WRITE_JIT_CAP = 8   # LRU cap on per-(n_pages, cache_len) writer jits

    def __init__(self, model, num_pages: int, page_size: int, *,
                 kv_bits=None, spmd=None):
        self.allocator = PageAllocator(num_pages, page_size)
        self.page_size = page_size
        self.kv_bits = kv_bits
        self.pool = model.init_pool(num_pages, page_size, kv_bits=kv_bits)
        # SPMD serving (engine/sharded.py): the pool lives sharded on
        # kv_heads over the mesh's model axis (every device holds a
        # 1/N-head slice of every page) and the span writer becomes its
        # shard_map twin — page ids stay host/replicated, the scatter is
        # shard-local.
        self._spmd = spmd
        if spmd is not None:
            self.pool = jax.device_put(self.pool, spmd.pool_shardings())
        self._write_jit = JitLRU(self.WRITE_JIT_CAP)

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    def write_prefill(self, cache, pages: Sequence[int], *,
                      start: int = 0) -> None:
        """Scatter one request's prefill cache (full layout, B=1, bucket-
        padded length) into its pages. Jitted per (n_pages, cache_len) shape
        with the pool donated, so the write is an in-place scatter rather
        than a full-pool copy per admission; the jits live in a small LRU so
        an open-ended mix of bucket/page-count shapes can't grow the retrace
        cache without bound. Bucket-padding garbage beyond the true prompt
        lands only inside the request's own pages and is masked (j <= pos)
        or overwritten by decode.

        ``start`` writes a per-chunk *span*: a cache holding tokens
        ``start..start+cache_len`` of the sequence lands at that offset
        within ``pages`` (chunk boundaries must be page-aligned for this
        writer; the chunked engine's own span writes happen inside the
        jitted prefill-with-cache forward, which scatters at arbitrary
        offsets — this host-side writer serves whole-prompt admission and
        chunk-granular replay/tests). Pages past the span's end are
        (re)padded, so spans must be written in chunk order.

        Quantized slots quantize on write: the bf16 prefill pages become
        int8/int4 codes + scale tiles in the same fused scatter (garbage
        slots quantize too, harmlessly — they stay behind the mask)."""
        from repro.kernels import ref as kref

        page = self.page_size
        if start % page:
            raise ValueError(
                f"span start {start} is not page-aligned (page={page})")
        pages = list(pages)[start // page:]
        n = len(pages)
        Sp = jax.tree.leaves(cache)[0].shape[2]
        span = n * page

        def make():
            def write(pool, cache, idx):
                def wr(pool_leaf, cache_leaf):
                    c = cache_leaf[:, 0]                # (G, Sp, K, hd)
                    if Sp >= span:
                        c = c[:, :span]
                    else:
                        c = jnp.pad(c, ((0, 0), (0, span - Sp))
                                    + ((0, 0),) * (c.ndim - 2))
                    c = c.reshape(c.shape[0], n, page, *c.shape[2:])
                    if isinstance(pool_leaf, dict):     # quantized slot
                        bits = kref.kv_bits_of(pool_leaf["q"], c.shape[-1])
                        q, scale = kref.quantize_kv(c, bits)
                        return {"q": pool_leaf["q"].at[:, idx].set(q),
                                "scale": pool_leaf["scale"]
                                .at[:, idx].set(scale)}
                    return pool_leaf.at[:, idx].set(c)
                return jax.tree.map(
                    wr, pool, cache,
                    is_leaf=lambda x: isinstance(x, dict) and "q" in x)
            if self._spmd is not None:
                return self._spmd.jit_pool_writer(write, cache)
            return jax.jit(write, donate_argnums=(0,))

        fn = self._write_jit.get((n, Sp), make)
        with quiet_donation():
            self.pool = fn(self.pool, cache,
                           jnp.asarray(np.asarray(pages, np.int32)))
