"""The serving engine: ties model, paged pool, and scheduler into a host
loop of interleaved prefill and decode ticks.

One ``step()``:
  1. admission — backfill free batch slots from the FIFO queue (page-
     and slot-gated, see scheduler.py). Admitted sequences owe their
     prompt to the pool: in chunked mode (default) nothing runs yet; with
     ``chunked_prefill=False`` the whole prompt runs here, padded to the
     policy's bucket, and is scattered into the request's pages;
  2. chunked prefill — every mid-prefill sequence advances by at most ONE
     ``policy.prefill_chunk``-token chunk (prefill-with-cache forward:
     the chunk's K/V are written into the sequence's pages and its
     attention walks the pool — resident prefix + chunk). The final chunk
     unembeds the last real prompt row and samples the first token; until
     then the sequence stays out of the decode batch, so one long prompt
     costs many bounded ticks instead of one decode-stalling bucket;
  3. growth — every decode-ready sequence whose position crosses a page
     boundary grows by one page; on pool exhaustion the youngest active
     sequence is preempted (freed + requeued as a prompt-extension; a
     mid-prefill victim simply restarts its prompt at re-admission) to
     make room, oldest-first so the head of the line always drains;
  4. decode tick — one batched ``decode_step_paged`` over the surviving
     prefill-complete slots (idle slots ride along against the scratch
     page and are ignored). The decode path walks pages with the Pallas
     paged-attention kernel (pure-JAX block walk off-TPU) — no dense
     chronological KV view is ever materialized;
  5. eviction — finished sequences free their pages/slot immediately, so
     the next step's admission backfills mid-flight.

The decode closure is jitted ONCE per engine (fixed shapes: the policy's
max_batch and page-table width), and so is the chunk-prefill closure
(fixed (1, chunk) tokens against the full-width page table, pool donated);
whole-prompt prefill and pool-writer jits are compiled per padding bucket
and held in small LRU caches so long-running engines with many bucket
shapes don't grow retrace caches without limit. When the policy's memory
roofline demanded it, weights are HAQ-quantized (serving/quant.py) and the
dequantizing ``dot`` is threaded through both paths. ``policy.kv_bits``
additionally selects the HAQ KV-quantized pool (serving/kvquant): pages
stored int8/int4 with per-token per-head scales, quantize-on-write in all
three writers (bucketed prefill, chunk forward, decode scatter), fused
dequant inside the paged-attention walk — the fp pool stays the exactness
baseline. On all-local-attention models, pages wholly behind the sliding
window are released back to the allocator each tick
(scheduler.trim_window).

Observability (serving/telemetry): every jitted dispatch — whole-prompt
prefill, prompt chunk, batched decode — emits a typed ``TickEvent``
into the engine's ``Telemetry`` recorder, carrying the *measured* wall
clock (fenced: the engine blocks on the dispatch's outputs before the
timer stops, so async jit dispatch is never billed as compute) next to
the ``admission.step_latency`` roofline *prediction* for the same
dispatch shape; request lifecycles (enqueue/admit/chunk/first_token/
preempt/requeue/finish/release) are recorded as per-rid spans, half by
the scheduler and half by this loop. ``stall_log`` (measured per-decode-
tick prefill stall seconds — the quantity ``prefill_stall_factor``
budgets, with the roofline's predicted stall recorded alongside in
``telemetry.stalls``) and ``first_token_s`` (per-request TTFT) survive
as thin views over that record; both feed the long-prompt section of
benchmarks/bench_engine_throughput.py, and ``telemetry.calibrate``
turns the tick trace into per-kind roofline scale factors.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import normalize_kv_bits, sublayer_kinds
from repro.serving.engine.admission import AdmissionPolicy, \
    RooflinePredictor
from repro.serving.engine.pool import JitLRU, PagedKVPool, quiet_donation
from repro.serving.engine.scheduler import ActiveSeq, Request, Scheduler
from repro.serving.telemetry import Telemetry, TickEvent
from repro.serving import quant as squant


def sample_token(logits_row, temperature: float, key) -> int:
    """One token from a (V,) f32 logits row (host array on the greedy path —
    np.argmax ties break first-max, same as the baseline's jnp.argmax)."""
    if temperature <= 0.0 or key is None:
        return int(np.argmax(logits_row))
    return int(jax.random.categorical(key, jnp.asarray(logits_row)
                                      / temperature))


class Engine:
    PREFILL_JIT_CAP = 8   # LRU cap on per-bucket prefill jits

    def __init__(self, model, params, policy: AdmissionPolicy, *,
                 temperature: float = 0.0, seed: int = 0, dot=None,
                 paged_kernel: str = "auto", reserve_upfront: bool = False,
                 chunked_prefill: bool = True, mesh=None,
                 telemetry: Optional[Telemetry] = None,
                 roofline_scales=None):
        cfg = model.cfg
        if cfg.is_encdec or cfg.family not in ("dense", "moe") \
                or cfg.frontend != "none":
            raise NotImplementedError(
                f"engine serves decoder-only attention-cache LMs; "
                f"{cfg.name} (family={cfg.family!r}, "
                f"frontend={cfg.frontend!r}) is an open item (ROADMAP)")
        self.model = model
        self.policy = policy
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed) if temperature > 0 else None
        # telemetry recorder (serving/telemetry): tick trace, sequence
        # spans, metrics. The default instance records in memory with a
        # no-op sink — cheap enough to leave on; pass your own Telemetry
        # (custom sink / clock) to stream or capture events.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        # roofline predictions per dispatch shape, memoized (telemetry
        # pairs them with measured wall clock on every tick event). Pass
        # ``roofline_scales`` (a telemetry.ScaleLookup fitted on THIS host
        # by telemetry.calibrate) to emit host-corrected predictions —
        # what the autotuner's validation engines do, so their traces
        # report calibrated rel_err instead of the raw roofline's.
        self._predict = RooflinePredictor(cfg, policy,
                                          scales=roofline_scales)

        if mesh is not None and policy.quant_bits < 16:
            raise NotImplementedError(
                "sharded engine with HAQ weight quantization: quantized "
                "weight dicts have no logical specs yet (ROADMAP); use "
                "kv_bits for sharded memory savings")
        if policy.quant_bits < 16:
            params = squant.quantize_params(
                params, default_bits=policy.quant_bits)
            assert dot is None, "quant policy supplies its own dot hook"
            dot = squant.dequant_dot
        self.params = params

        # Allocate only the pages max_batch concurrent sequences can use,
        # capped by what the target's HBM holds (policy.num_pages) and
        # floored at one full-length sequence plus scratch — the growth
        # loop's guarantee that a lone sequence can always reach
        # max_model_len without preempting itself.
        needed = policy.max_batch * policy.pages_per_seq + 1
        num_pages = max(min(policy.num_pages, needed),
                        policy.pages_per_seq + 1)
        self.kv_bits = normalize_kv_bits(cfg, policy.kv_bits)
        # SPMD serving (serving/engine/sharded.py): params and the paged
        # pool sharded over the mesh, decode/prefill/writer jits shard_map'd,
        # the host-side scheduler/page-table state untouched. The unsharded
        # engine stays the token-exact baseline the sharded one is asserted
        # bit-identical to.
        self.mesh = mesh
        spmd = None
        if mesh is not None:
            from repro.serving.engine import sharded
            spmd = sharded.SpmdEngine(model, mesh, kv_bits=self.kv_bits,
                                      kernel=paged_kernel, dot=dot)
            params = self.params = spmd.shard_params(params)
        self.kv = PagedKVPool(model, num_pages, policy.page_size,
                              kv_bits=self.kv_bits, spmd=spmd)
        self.scheduler = Scheduler(self.kv.allocator, policy.max_batch,
                                   policy.max_model_len,
                                   reserve_upfront=reserve_upfront,
                                   telemetry=self.telemetry)
        # mesh tags stamped on every tick event (engine/sharded.py)
        self._tags = spmd.event_tags() if spmd is not None else {}
        # Window-trim page freeing (ROADMAP): pages are shared across
        # layers, so blocks behind the sliding window can only be released
        # when EVERY layer is local — one global layer pins the history.
        # Off under reserve_upfront (the legacy worst-case baseline keeps
        # its reservations untouched).
        kinds = sublayer_kinds(cfg)
        self._trim_window = cfg.window_size if (
            not reserve_upfront and kinds
            and all(k["attn"] == "local" for k in kinds)) else None

        # jit once: fixed (max_batch, pages_per_seq) shapes for decode;
        # prefill compiles per padding bucket (LRU below). The pool is
        # donated so decode ticks update it in place instead of double-
        # buffering it. Under a mesh every closure is the shard_map'd twin
        # with the identical signature, so the host loop never branches.
        def prefill_body(p, toks, last_idx, dot_):
            # unembed only the last real prompt position — the prompt is
            # padded to the bucket, so a full (B, Sp, V) unembed would be
            # bucket/1 overcompute per admission.
            hidden, cache, _, _ = model.forward(
                p, {"tokens": toks}, want_cache=True, unembed_mode="none",
                cache_layout="full", dot=dot_)
            h = jnp.take_along_axis(hidden, last_idx.reshape(1, 1, 1),
                                    axis=1)
            return model.unembed(p, h, dot=dot_), cache

        # one jit instance per padding bucket, bounded: evicting an entry
        # drops its compiled executable (a single shared jax.jit would keep
        # every bucket's trace alive for the engine's lifetime).
        self._prefill_jits = JitLRU(self.PREFILL_JIT_CAP)
        self.chunked = chunked_prefill
        if spmd is None:
            self._decode = jax.jit(
                lambda p, pool, pt, tok, pos: model.decode_step_paged(
                    p, pool, pt, tok, pos, dot=dot, kernel=paged_kernel),
                donate_argnums=(1,))
            self._make_prefill = lambda: jax.jit(
                lambda p, t, i: prefill_body(p, t, i, dot))
            self._chunk_prefill = jax.jit(
                lambda p, pool, pt, toks, pos: model.prefill_chunk_paged(
                    p, pool, pt, toks, pos, dot=dot, kernel=paged_kernel),
                donate_argnums=(1,))
            self._unembed_row = jax.jit(
                lambda p, h, idx: model.unembed(
                    p, jnp.take_along_axis(h, idx.reshape(1, 1, 1), axis=1),
                    dot=dot))
        else:
            self._decode = spmd.jit_decode()
            self._make_prefill = lambda: spmd.make_prefill(
                lambda p, t, i: prefill_body(spmd.gathered(p), t, i,
                                             spmd.dot))
            self._chunk_prefill = spmd.jit_prefill_chunk()
            self._unembed_row = spmd.jit_unembed_row()
        self.stats = {"decode_ticks": 0, "decode_tokens": 0,
                      "prefills": 0, "prefill_chunks": 0, "admitted": 0,
                      "preemptions": 0, "grown_pages": 0,
                      "trimmed_pages": 0}
        self._outputs: Dict[int, np.ndarray] = {}
        # per-step telemetry bookkeeping: step index, admissions this
        # step, and the marks tick events difference page/preemption
        # counters against (each event reports deltas since the previous
        # event, so admission-time allocations land on the step's first
        # tick and growth/preempt frees on the decode tick that caused
        # them).
        self._step_idx = 0
        self._step_admitted = 0
        self._alloc_mark = self._free_mark = 0
        self._trim_mark = self._preempt_mark = 0

    # --------------------------------------------------- telemetry views --
    @property
    def stall_log(self) -> List[float]:
        """Measured per-decode-tick prefill stall seconds — the exact
        pre-telemetry list, as a view over ``telemetry.stalls`` (each
        record also carries the roofline's *predicted* stall for the
        same chunks; this view is measurement only)."""
        return self.telemetry.stall_log_view()

    @property
    def first_token_s(self) -> Dict[int, float]:
        """rid -> time-to-first-token seconds (trace clock), as a view
        over the telemetry spans; a preempted request keeps the
        timestamp of the first token it was actually served."""
        return self.telemetry.first_token_view()

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def reset_stats(self) -> None:
        """Zero the counters, telemetry, and held outputs (benchmarks
        re-time a warmed engine instance so jit compiles stay out of the
        clock). Allocator lifetime counters are not zeroed (pool state
        persists) — the delta marks re-anchor on them instead, and the
        free-page low-water mark restarts at the current free count."""
        for k in self.stats:
            self.stats[k] = 0
        self.scheduler.num_preempted = 0
        self._outputs.clear()
        self.telemetry.reset()
        self._step_idx = 0
        self._step_admitted = 0
        alloc = self.kv.allocator
        self._alloc_mark = alloc.total_allocated
        self._free_mark = alloc.total_freed
        self._trim_mark = self._preempt_mark = 0
        alloc.min_free = alloc.num_free

    # --------------------------------------------------------------- step --
    def step(self, now: float = float("inf")) -> List[int]:
        """One scheduler tick: admit, run prefill work (the whole prompt in
        one bucketed forward, or — chunked mode, the default — at most ONE
        prompt chunk per mid-prefill sequence), then one batched decode
        over the prefill-complete sequences. Returns the rids that
        finished during this step. Finished sequences are released the
        moment they finish — before the decode tick's growth phase — so
        their pages backfill growth instead of tempting the preemption
        picker."""
        self.telemetry.start_clock()
        self._step_idx += 1
        self._step_admitted = 0
        out: List[int] = []
        ready_before = len(self.scheduler.decode_ready())
        stall_pred = 0.0
        t_prefill = time.monotonic()
        for seq in self.scheduler.admit(now):
            self.stats["admitted"] += 1
            self._step_admitted += 1
            if not self.chunked:
                stall_pred += self._run_prefill(seq)
                if seq.is_done():
                    out.append(self._finish(seq))
        if self.chunked:
            for seq in self.scheduler.prefill_pending():
                stall_pred += self._run_prefill_chunk(seq)
                if seq.prefill_done and seq.is_done():
                    out.append(self._finish(seq))
        t_prefill = time.monotonic() - t_prefill
        live = self.scheduler.decode_ready()
        if live:
            finished: List[ActiveSeq] = []
            ticks_before = self.stats["decode_ticks"]
            self._decode_tick(live, finished)
            if self.stats["decode_ticks"] > ticks_before and ready_before:
                # per-decode-tick stall: seconds this tick's already-ready
                # sequences waited on prefill work (0.0 when none ran) —
                # the quantity prefill_stall_factor budgets per tick,
                # recorded next to the roofline's prediction for the same
                # prefill work so calibration sees both sides.
                self.telemetry.stall(t_prefill, stall_pred)
            for seq in finished:
                out.append(self._finish(seq))
        self._update_gauges()
        return out

    # ---------------------------------------------------- telemetry emit --
    def _tick_deltas(self) -> Dict[str, int]:
        """Page/preemption deltas since the previous tick event (marks
        advance here, so each event owns exactly its own deltas)."""
        a = self.kv.allocator
        trimmed = self.stats["trimmed_pages"]
        preempted = self.scheduler.num_preempted
        d = {"pages_allocated": a.total_allocated - self._alloc_mark,
             "pages_freed": a.total_freed - self._free_mark,
             "pages_trimmed": trimmed - self._trim_mark,
             "preempted": preempted - self._preempt_mark}
        self._alloc_mark = a.total_allocated
        self._free_mark = a.total_freed
        self._trim_mark = trimmed
        self._preempt_mark = preempted
        return d

    def _emit_tick(self, kind: str, t_start: float, measured_s: float,
                   predicted_s: float, *, batch: int, padded_batch: int,
                   q_len: int, tokens: int, rids) -> None:
        a = self.kv.allocator
        self.telemetry.tick(TickEvent(
            kind=kind, step=self._step_idx, t_start=t_start,
            measured_s=measured_s, predicted_s=predicted_s, batch=batch,
            padded_batch=padded_batch, q_len=q_len, tokens=tokens,
            rids=tuple(rids), admitted=self._step_admitted,
            queue_depth=self.scheduler.num_queued, pool_free=a.num_free,
            pool_allocated=a.num_allocated, tags=self._tags,
            **self._tick_deltas()))

    def _update_gauges(self) -> None:
        """Per-step gauges that aren't per-tick deltas: pool occupancy /
        fragmentation (token-granular — allocated pages may be mostly
        empty while sequences are young) and the jit-cache hit/miss
        counters (satellite: JitLRU observability — steady-state decode
        must not retrace)."""
        m = self.telemetry.metrics
        a = self.kv.allocator
        page = a.page_size
        used = 0
        for seq in self.scheduler.active.values():
            live_pages = sum(p != 0 for p in seq.pages)
            trimmed = len(seq.pages) - live_pages
            used += max(min(seq.pos - trimmed * page, live_pages * page), 0)
        cap = a.num_allocated * page
        occ = used / cap if cap else 0.0
        m.gauge("pool.occupancy").set(occ)
        m.gauge("pool.fragmentation").set(1.0 - occ if cap else 0.0)
        m.gauge("pool.min_free").set(a.min_free)
        m.gauge("jit.prefill.hits").set(self._prefill_jits.hits)
        m.gauge("jit.prefill.misses").set(self._prefill_jits.misses)
        m.gauge("jit.pool_writer.hits").set(self.kv._write_jit.hits)
        m.gauge("jit.pool_writer.misses").set(self.kv._write_jit.misses)
        # the once-jitted closures: retrace count straight from jax (a
        # steady-state engine holds these at 1)
        for name, fn in (("decode", self._decode),
                         ("chunk", self._chunk_prefill)):
            size = getattr(fn, "_cache_size", lambda: -1)()
            m.gauge(f"jit.{name}.cache_size").set(size)

    def _finish(self, seq: ActiveSeq) -> int:
        self.telemetry.seq_event(seq.req.rid, "finish",
                                 generated=len(seq.generated))
        self.scheduler.release(seq)
        self._outputs[seq.req.rid] = np.concatenate(
            [np.asarray(seq.req.prompt, np.int32),
             np.asarray(seq.generated, np.int32)])
        return seq.req.rid

    def _first_token(self, seq: ActiveSeq, logits_row) -> None:
        """Sample the prompt's first generated token (prefill just
        finished) and stamp the request's time-to-first-token."""
        tok = sample_token(logits_row, self.temperature,
                           self._step_key(seq))
        seq.generated.append(tok)
        seq.pos = len(seq.req.prompt)
        self.stats["prefills"] += 1
        # a preempted sequence re-prefills its prompt-extension later and
        # emits another first_token edge, but TTFT views take the FIRST
        # edge — the request's first token was already served.
        self.telemetry.seq_event(seq.req.rid, "first_token", token=tok)

    def _run_prefill(self, seq: ActiveSeq) -> float:
        """Whole-prompt prefill (chunked_prefill=False): one forward over
        the prompt padded to the policy's bucket, scattered into the
        sequence's pages afterwards. One long prompt stalls every resident
        decode for its full prefill latency — kept as the pre-chunking
        baseline the bench compares against. Returns the roofline's
        predicted seconds for the dispatch (the step's stall budget)."""
        prompt = np.asarray(seq.req.prompt, np.int32)
        S = len(prompt)
        chunk = self.policy.prefill_chunk
        Sp = -(-S // chunk) * chunk
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :S] = prompt
        t_start = time.monotonic()
        prefill = self._prefill_jits.get(Sp, self._make_prefill)
        logits, cache = prefill(self.params, jnp.asarray(toks),
                                jnp.asarray(S - 1, jnp.int32))
        self.kv.write_prefill(cache, seq.pages)
        # fence: the writer donated the pool, so blocking on (logits, pool)
        # covers the whole admission dispatch before the timer stops
        jax.block_until_ready((logits, self.kv.pool))
        pred = self._predict("prefill", 1, Sp)
        self._emit_tick("prefill", t_start, time.monotonic() - t_start,
                        pred, batch=1, padded_batch=1, q_len=Sp, tokens=S,
                        rids=(seq.req.rid,))
        seq.prefill_progress = S
        self._first_token(seq, np.asarray(logits[0, 0]))
        return pred

    def _run_prefill_chunk(self, seq: ActiveSeq) -> float:
        """One prompt chunk through the prefill-with-cache forward: the
        chunk's K/V land in the sequence's pages and its attention walks
        the pool (resident prefix + chunk). The final chunk unembeds the
        last real prompt row and samples the first generated token; until
        then the sequence stays out of the decode batch. Returns the
        roofline's predicted seconds for the chunk (the step's stall
        budget accumulates these)."""
        prompt = np.asarray(seq.req.prompt, np.int32)
        S = len(prompt)
        C = self.policy.prefill_chunk
        start = seq.prefill_progress
        end = min(start + C, S)
        toks = np.zeros((1, C), np.int32)
        toks[0, :end - start] = prompt[start:end]
        maxp = self.policy.pages_per_seq
        pt = np.zeros((1, maxp), np.int32)
        pt[0, :len(seq.pages)] = seq.pages
        t_start = time.monotonic()
        with quiet_donation():
            hidden, self.kv.pool = self._chunk_prefill(
                self.params, self.kv.pool, jnp.asarray(pt),
                jnp.asarray(toks), jnp.asarray([start], jnp.int32))
        # sync before the step's stall timer stops: dispatch is async, and
        # an unblocked intermediate chunk would bill its compute to the
        # decode tick instead of the stall it actually causes.
        jax.block_until_ready(hidden)
        pred = self._predict("chunk", 1, C)
        self._emit_tick("chunk", t_start, time.monotonic() - t_start,
                        pred, batch=1, padded_batch=1, q_len=C,
                        tokens=end - start, rids=(seq.req.rid,))
        self.telemetry.seq_event(seq.req.rid, "chunk", start=start, end=end)
        seq.prefill_progress = end
        seq.pos = end
        self.stats["prefill_chunks"] += 1
        if end == S:
            logits = self._unembed_row(self.params, hidden,
                                       jnp.asarray(S - 1 - start, jnp.int32))
            self._first_token(seq, np.asarray(logits[0, 0]))
        return pred

    def _is_live(self, seq: ActiveSeq) -> bool:
        return self.scheduler.active.get(seq.slot) is seq

    def _decode_tick(self, live: List[ActiveSeq],
                     finished: List[ActiveSeq]) -> None:
        # Growth phase, oldest first: crossing a page boundary claims a new
        # page; exhaustion preempts the youngest active sequence — the
        # grower itself, if it is the youngest, so pages only ever flow
        # from younger to older and the FIFO head keeps draining. Victims
        # already in `live` are filtered out below; their requests ride the
        # queue back in on a later step.
        live = sorted(live, key=lambda s: s.birth)
        for seq in live:
            if not self._is_live(seq):
                continue                    # preempted earlier this tick
            if self._trim_window:
                # release blocks wholly behind the sliding window before
                # asking for growth — trimmed pages backfill the pool the
                # same tick they die, shrinking the preemption pressure.
                self.stats["trimmed_pages"] += self.scheduler.trim_window(
                    seq, self._trim_window)
            before = len(seq.pages)
            while not self.scheduler.ensure_capacity(seq):
                victim = self.scheduler.youngest_active()
                if victim is seq and self.scheduler.num_active == 1:
                    raise RuntimeError(
                        "page pool smaller than one max-length sequence")
                self.scheduler.preempt(victim)
                if victim is seq:
                    break                   # yielded to older sequences
            if self._is_live(seq):
                self.stats["grown_pages"] += len(seq.pages) - before
        self.stats["preemptions"] = self.scheduler.num_preempted
        ready = [s for s in live if self._is_live(s)]
        if not ready:
            return

        B = self.policy.max_batch
        maxp = self.policy.pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        # idle slots ride along against the scratch page; they carry the
        # minimum live position (not 0) so the block walk's batch-wide
        # window-trim bound stays tight for local-attention layers.
        positions = np.full((B,), min(s.pos for s in ready), np.int32)
        pt = np.zeros((B, maxp), np.int32)       # 0 -> scratch page
        for seq in ready:
            tokens[seq.slot, 0] = seq.last_token
            positions[seq.slot] = seq.pos
            pt[seq.slot, :len(seq.pages)] = seq.pages
        t_start = time.monotonic()
        with quiet_donation():
            logits, self.kv.pool = self._decode(
                self.params, self.kv.pool, jnp.asarray(pt),
                jnp.asarray(tokens), jnp.asarray(positions))
        # fence before the host transfer so the tick's measured duration
        # is dispatch + compute, not whenever the async stream drains
        jax.block_until_ready(logits)
        measured = time.monotonic() - t_start
        self.stats["decode_ticks"] += 1
        # prediction priced at the PADDED jit batch — idle slots ride
        # along in the fixed-shape dispatch, so B is what actually runs
        self._emit_tick("decode", t_start, measured,
                        self._predict("decode", B, 1), batch=len(ready),
                        padded_batch=B, q_len=1, tokens=len(ready),
                        rids=(s.req.rid for s in ready))
        rows = np.asarray(logits[:, 0])      # one host transfer per tick
        for seq in ready:
            tok = sample_token(rows[seq.slot], self.temperature,
                               self._step_key(seq))
            seq.generated.append(tok)
            seq.pos += 1
            self.stats["decode_tokens"] += 1
            if seq.is_done():
                finished.append(seq)

    def _step_key(self, seq: ActiveSeq):
        if self._key is None:
            return None
        k = jax.random.fold_in(self._key, seq.req.rid)
        return jax.random.fold_in(k, len(seq.generated))

    # ---------------------------------------------------------------- run --
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> Dict[int, np.ndarray]:
        """Serve a trace to completion. With ``realtime=True`` requests are
        admitted no earlier than their ``arrival`` offset (wall clock);
        otherwise arrivals are ignored (burst)."""
        for r in requests:
            self.submit(r)
        t0 = time.monotonic()
        while self.scheduler.has_work():
            now = (time.monotonic() - t0) if realtime else float("inf")
            if not self.step(now) and not self.scheduler.active:
                time.sleep(1e-4)             # waiting on future arrivals
        return {r.rid: self._outputs[r.rid] for r in requests}
