"""SPMD serving: shard_map the paged KV pool and the engine's jitted ticks
over a device mesh, keeping every host-side decision (admission, growth,
preemption, window-trim, chunk accounting) untouched.

Design: **exactness-first tensor parallelism**. The acceptance bar for the
sharded engine is that greedy outputs on an N-device mesh are *bit-identical*
to the 1-device engine (across fp and quantized pools, chunked prefill, GQA,
windows, and forced preemption), so the partitioning only ever splits
computations along *batch-like* dimensions — never along a floating-point
reduction:

  * q/k/v projections contract over the replicated ``embed`` dim and are
    sharded on their **output** head dims (``heads``/``kv_heads`` on the
    ``model`` axis, per ``distributed.sharding.CANDIDATES``): each device
    computes an identical slice of the identical full computation.
  * the paged-attention walk is fully parallel over heads: each device walks
    its local kv-head group of the pool (softmax and PV reductions run over
    page slots and head_dim, both unsharded), so the decode-dominant KV
    HBM traffic — the roofline term that sizes the pool — is truly divided
    by the ``model`` axis.
  * contraction-sharded matmuls (attn out-projection over ``heads``, FFN
    down-projection over ``d_ff``) would need a partial-sum all-reduce,
    which is NOT bit-stable; instead the *inputs* are all-gathered (pure
    data movement) and the contraction runs whole on every device, exactly
    as on one device. Weights stay sharded **at rest** (per-device HBM is
    what the admission roofline prices); they are gathered at use like FSDP.
  * everything else (embedding lookup, norms, residuals, sampling inputs)
    is replicated.

The ``data`` mesh axis is a pure at-rest FSDP axis for parameters (the
``embed``/FSDP candidates in ``CANDIDATES``); batch-sharding the decode tick
across ``data`` is the async-host-loop follow-on (ROADMAP).

The KV pool shards on ``kv_heads`` only. The ``cache_seq`` fall-through in
``CANDIDATES`` belongs to the *dense* ring/full-cache decode path (see
``make_ac``'s flash-decoding hints and the dry-run decode cells): splitting
page slots across devices would split the online-softmax reduction and break
bit-exactness, so the engine instead *requires* ``num_kv_heads %
mesh.shape["model"] == 0`` (``validate_mesh``) and keeps pages whole.

Pool / page-table layout per device (mesh ``model=N``)::

    pool["sub{j}"]["k"|"v"]         (G, num_pages, page, K/N, hd)
    quantized: {"q":   (G, num_pages, page, K/N, hd_store) int8,
                "scale":(G, num_pages, page, K/N) f32}     # scale tiles
    page_table, positions, tokens   replicated (host-built every tick)

Every device holds a 1/N kv-head slice of EVERY page, so one host-side page
allocation covers all shards and the allocator/scheduler/preemption logic is
unchanged — while per-device page bytes drop N×, which is exactly how
``derive_policy(mesh_model=N)`` finds ~N× the pool capacity in the same
per-device HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shlib
from repro.models import transformer

F32 = jnp.float32
MODEL_AXIS = "model"

# Leaves whose ``model``-axis sharding is an *output* dim of their matmul:
# they are used as local slices (never gathered on that dim). Everything
# else sharded on ``model`` — and every ``data``/FSDP-sharded dim — is
# all-gathered at use inside the shard_map body.
_LOCAL_KEYS = ("'wq'", "'wk'", "'wv'", "'w_in'", "'w_gate'")
_LOCAL_AXES = ("heads", "kv_heads", "d_ff")

# Full-layout prefill caches (G, B, Sp, K, hd): sharded on kv_heads only,
# like the pool itself (transformer.pool_axes explains why cache_seq's
# fall-through never applies to paged serving).
_CACHE_KV_AXES = ("layer", None, None, "kv_heads", "head_dim")


def _axes_tuple(a):
    return a if isinstance(a, tuple) else (a,)


def validate_mesh(cfg, mesh: Mesh) -> None:
    """The exactness contract the SPMD engine needs from (cfg, mesh)."""
    unknown = set(mesh.shape) - {"data", "model"}
    if unknown:
        raise ValueError(f"serving mesh axes must be data/model, "
                         f"got {sorted(mesh.shape)}")
    tp = mesh.shape.get(MODEL_AXIS, 1)
    if cfg.num_kv_heads % tp or cfg.num_heads % tp:
        raise ValueError(
            f"{cfg.name}: heads ({cfg.num_heads}) and kv heads "
            f"({cfg.num_kv_heads}) must divide the model axis ({tp}); the "
            f"paged walk shards on kv_heads only — page slots stay whole "
            f"so the online softmax keeps its 1-device reduction order")
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"sharded engine serves dense/moe decoders; {cfg.name} "
            f"(family={cfg.family!r}) is an open item (ROADMAP)")


def partition_specs(abstract, logical, mesh: Mesh):
    """Pytree of full-rank PartitionSpecs via the divisibility-aware
    ``choose_spec`` rules (shard_map wants explicit trailing Nones)."""
    flat_a, tdef = jax.tree.flatten(abstract)
    flat_l = tdef.flatten_up_to(logical)
    out = []
    for a, l in zip(flat_a, flat_l):
        if l is None:
            l = (None,) * a.ndim
        sp = shlib.choose_spec(a.shape, l, mesh)
        out.append(P(*(tuple(sp) + (None,) * (a.ndim - len(sp)))))
    return jax.tree.unflatten(tdef, out)


def gather_plans(abstract, logical, specs):
    """Per-leaf ``((dim, mesh_axis), ...)`` all-gathers to run at the top of
    a shard_map body: every sharded dim EXCEPT the local-use output dims of
    the q/k/v/FFN-up projections (see module docstring)."""
    flat_a, tdef = jax.tree.flatten(abstract)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(abstract)[0]]
    flat_l = tdef.flatten_up_to(logical)
    flat_s = tdef.flatten_up_to(specs)
    plans = []
    for a, l, s, ks in zip(flat_a, flat_l, flat_s, paths):
        if l is None:
            l = (None,) * a.ndim
        local = any(k in ks for k in _LOCAL_KEYS)
        plan = []
        for dim, axes in enumerate(tuple(s)):
            if axes is None:
                continue
            if local and l[dim] in _LOCAL_AXES:
                continue
            for ax in _axes_tuple(axes):
                plan.append((dim, ax))
        plans.append(tuple(plan))
    return jax.tree.unflatten(tdef, plans)


def gather_at_use(tree, plans):
    """Run each leaf's gather plan (inside a shard_map body). all_gather is
    pure data movement — bit-exact by construction."""
    def g(x, plan):
        for dim, ax in plan:
            x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
        return x
    return jax.tree.map(g, tree, plans)


def tp_dot(axis: str = MODEL_AXIS):
    """The ``dot`` hook for SPMD serving. Reproduces each site's default
    einsum exactly (including lm_head's f32 accumulation) so the sharded
    engine stays bit-comparable to the unsharded `dot=None` path; the two
    contraction-sharded sites gather their activations first — the shape
    test keeps replicated-fall-through weights (e.g. an odd d_ff) on the
    plain einsum."""
    def dot(a, w, name):
        if name in ("attn_q", "attn_k", "attn_v"):
            return jnp.einsum("bsd,dnh->bsnh", a, w)
        if name == "attn_o":
            if a.shape[2] != w.shape[0]:                  # local heads
                a = jax.lax.all_gather(a, axis, axis=2, tiled=True)
            return jnp.einsum("bsnh,nhd->bsd", a, w)
        if name in ("ffn_in", "ffn_gate"):
            return jnp.einsum("...d,df->...f", a, w)
        if name == "ffn_out":
            if a.shape[-1] != w.shape[0]:                 # local d_ff
                a = jax.lax.all_gather(a, axis, axis=a.ndim - 1,
                                       tiled=True)
            return jnp.einsum("...d,df->...f", a, w)
        if name == "lm_head":
            return jnp.einsum("bsd,dv->bsv", a, w,
                              preferred_element_type=F32)
        if name in ("moe_in", "moe_gate"):
            return jnp.einsum("ecd,edf->ecf", a, w)
        if name == "moe_out":
            return jnp.einsum("ecf,efd->ecd", a, w)
        raise ValueError(f"unknown dot site {name!r}")
    return dot


class SpmdEngine:
    """Sharding context the Engine holds when built with a mesh: param /
    pool placement plus the shard_map'd decode, chunk-prefill, whole-prompt
    prefill, pool-writer, and unembed closures.

    All jits share one contract: page table / tokens / positions replicated,
    params per ``specs_for`` (gathered at use where a contraction would
    split), pool sharded on ``kv_heads`` over ``model``.
    """

    def __init__(self, model, mesh: Mesh, *, kv_bits=None,
                 kernel: str = "auto", dot=None):
        if dot is not None:
            raise NotImplementedError(
                "sharded engine with a weight-quant dot hook: HAQ weight "
                "dicts have no logical specs yet (ROADMAP)")
        validate_mesh(model.cfg, mesh)
        self.model = model
        self.cfg = model.cfg
        self.mesh = mesh
        self.kernel = kernel
        self.kv_bits = kv_bits
        abstract = model.abstract_params()
        logical = model.logical_specs()
        self.param_pspecs = partition_specs(abstract, logical, mesh)
        self._plans = gather_plans(abstract, logical, self.param_pspecs)
        self.pool_pspecs = partition_specs(
            transformer.pool_specs(self.cfg, 2, 2, kv_bits=kv_bits),
            transformer.pool_axes(self.cfg, kv_bits), mesh)
        self.dot = tp_dot()

    # ----------------------------------------------------------- placement --
    def _named(self, pspecs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspecs)

    def shard_params(self, params):
        """Place params at rest: TP dims local, FSDP dims split over data."""
        return jax.device_put(params, self._named(self.param_pspecs))

    def pool_shardings(self):
        return self._named(self.pool_pspecs)

    def gathered(self, params):
        return gather_at_use(params, self._plans)

    def _cache_pspecs(self, cache):
        """Full-layout prefill caches: (G, B, Sp, K, hd) sharded on K."""
        return partition_specs(
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
                         if hasattr(a, "shape") else a, cache),
            jax.tree.map(lambda a: _CACHE_KV_AXES, cache), self.mesh)

    # ---------------------------------------------------------------- jits --
    def jit_decode(self):
        model, dot, kernel = self.model, self.dot, self.kernel

        def body(p, pool, pt, tok, pos):
            return model.decode_step_paged(self.gathered(p), pool, pt, tok,
                                           pos, dot=dot, kernel=kernel)

        return jax.jit(shard_map(
            body, self.mesh,
            in_specs=(self.param_pspecs, self.pool_pspecs, P(), P(), P()),
            out_specs=(P(), self.pool_pspecs), check_rep=False),
            donate_argnums=(1,))

    def jit_prefill_chunk(self):
        model, dot, kernel = self.model, self.dot, self.kernel

        def body(p, pool, pt, toks, pos):
            return model.prefill_chunk_paged(self.gathered(p), pool, pt,
                                             toks, pos, dot=dot,
                                             kernel=kernel)

        return jax.jit(shard_map(
            body, self.mesh,
            in_specs=(self.param_pspecs, self.pool_pspecs, P(), P(), P()),
            out_specs=(P(), self.pool_pspecs), check_rep=False),
            donate_argnums=(1,))

    def jit_unembed_row(self):
        model, dot = self.model, self.dot

        def body(p, h, idx):
            h = jnp.take_along_axis(h, idx.reshape(1, 1, 1), axis=1)
            return model.unembed(self.gathered(p), h, dot=dot)

        return jax.jit(shard_map(
            body, self.mesh, in_specs=(self.param_pspecs, P(), P()),
            out_specs=P(), check_rep=False))

    def make_prefill(self, prefill_fn):
        """Whole-prompt (non-chunked) bucketed prefill: logits replicated,
        cache K-sharded so the pool writer scatters shard-locally. One jit
        per padding bucket, held in the engine's JitLRU like the unsharded
        path."""
        cache = self.model.cache_specs(1, 2)
        cspecs = self._cache_pspecs(
            {k: v for k, v in cache.items() if k.startswith("sub")})
        return jax.jit(shard_map(
            prefill_fn, self.mesh,
            in_specs=(self.param_pspecs, P(), P()),
            out_specs=(P(), cspecs), check_rep=False))

    def jit_pool_writer(self, write_fn, cache):
        """shard_map'd span writer for one (n_pages, cache_len) shape:
        ``write_fn(pool, cache, idx) -> pool`` with the full-layout cache
        and the pool both sharded on kv_heads; the scatter at replicated
        page ids is purely local. Donation rides the engine's JitLRU entry
        exactly like the unsharded writer."""
        cspecs = self._cache_pspecs(cache)
        return jax.jit(shard_map(
            write_fn, self.mesh,
            in_specs=(self.pool_pspecs, cspecs, P()),
            out_specs=self.pool_pspecs, check_rep=False),
            donate_argnums=(0,))

    # ------------------------------------------------------------ describe --
    def event_tags(self) -> dict:
        """Mesh tags stamped on every telemetry tick event
        (serving/telemetry): lets a Chrome trace / calibration report
        from a sharded run be told apart from — and grouped against —
        single-device runs. One host drives all shards (the page table
        and scheduler are replicated), so tags describe the mesh, not a
        shard index."""
        return {"mesh_model": self.mesh.shape.get(MODEL_AXIS, 1),
                "mesh_data": self.mesh.shape.get("data", 1),
                "mesh_devices": self.mesh.size}

    def describe(self) -> str:
        tp = self.mesh.shape.get(MODEL_AXIS, 1)
        dp = self.mesh.shape.get("data", 1)
        return (f"mesh(model={tp}, data={dp}): pool kv_heads/{tp}, "
                f"params at rest per specs_for (gather-at-use), "
                f"page table + scheduler replicated on host")
