"""Continuous-batching serving engine with a paged KV-cache pool.

This is the deployed counterpart of the paper's hardware-in-the-loop search:
the same roofline simulator (`core/hardware_model.py`) that scores NAS/HAQ
candidates at *search* time sizes the runtime at *serve* time — KV pool
capacity from the target's HBM, max in-flight batch from the decode-latency
roofline, prompt padding buckets from the prefill roofline, and a HAQ bit
policy (via `serving/quant.py`) when the memory roofline demands it.

Page-table layout
-----------------
The KV cache is a pool of fixed-size **pages** preallocated once per layer::

    pool["sub{j}"]["k"|"v"] : (n_groups, num_pages, page_size, K, hd) bf16

``num_pages`` and ``page_size`` are shared by every layer: a single logical
page allocation covers all layers, so the allocator hands out one list of
physical page ids per request and the per-layer pools index it identically
(vLLM's layout, transposed into the repo's scan-stacked group convention).

Each in-flight sequence owns ``ceil((prompt + max_new) / page_size)`` pages,
reserved at admission so decode can never OOM mid-flight. The scheduler
packs active sequences into a fixed-width batch; a decode tick calls
``Model.decode_step_paged`` with:

    page_table : (B, max_pages) int32 — physical page of logical block i;
                 unused tails (and idle batch slots) point at the scratch
                 page 0, which is never allocated to a request
    positions  : (B,) int32 — per-sequence absolute position, so every slot
                 can be at a different decode depth (continuous batching)

Token ``pos`` of sequence ``b`` lives at page ``page_table[b, pos // page]``
slot ``pos % page``. RoPE is applied at cache-write time with absolute
positions, so gathering pages back into chronological order is bit-exact
with the dense cache — the engine's greedy outputs are token-identical to
the sequential `launch.serve.generate` baseline (asserted in
tests/test_engine.py).

Modules: `pool` (page allocator + device pool), `scheduler` (FIFO admission
/ eviction / backfill bookkeeping), `admission` (roofline-derived policy),
`engine` (the host loop tying them to the model).
"""
from repro.serving.engine.admission import AdmissionPolicy, derive_policy
from repro.serving.engine.engine import Engine
from repro.serving.engine.pool import PageAllocator, PagedKVPool
from repro.serving.engine.scheduler import Request, Scheduler

__all__ = ["AdmissionPolicy", "derive_policy", "Engine", "PageAllocator",
           "PagedKVPool", "Request", "Scheduler"]
