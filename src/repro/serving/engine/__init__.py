"""Continuous-batching serving engine with a paged KV-cache pool.

This is the deployed counterpart of the paper's hardware-in-the-loop search:
the same roofline simulator (`core/hardware_model.py`) that scores NAS/HAQ
candidates at *search* time sizes the runtime at *serve* time — KV pool
capacity from the target's HBM, max in-flight batch from the decode-latency
roofline, prompt padding buckets from the prefill roofline, and a HAQ bit
policy (via `serving/quant.py`) when the memory roofline demands it.

Page-table layout
-----------------
The KV cache is a pool of fixed-size **pages** preallocated once per layer::

    pool["sub{j}"]["k"|"v"] : (n_groups, num_pages, page_size, K, hd) bf16

``num_pages`` and ``page_size`` are shared by every layer: a single logical
page allocation covers all layers, so the allocator hands out one list of
physical page ids per request and the per-layer pools index it identically
(vLLM's layout, transposed into the repo's scan-stacked group convention).

Pages are the unit of **memory and compute**. Allocation is dynamic: a
request is admitted with only the pages its prompt (plus the first decode
slot) needs, then grows page-by-page as decode crosses block boundaries.
On pool exhaustion the youngest active sequence is preempted — its pages
are freed and it is requeued at the FIFO front with its generated tokens
folded into the prompt, so its next admission re-prefills the extension
(recompute) and greedy outputs are unchanged. Freed pages are recycled
without clearing: a new owner only ever reads slots at ``j <= pos`` that it
has itself written (prefill spans, then decode writes in position order),
so stale KV from a previous owner stays behind the mask. The legacy
worst-case policy — ``ceil((prompt + max_new) / page_size)`` pages reserved
at admission, no preemption — remains available as ``reserve_upfront``.

Chunked-prefill lifecycle
-------------------------
A sequence's prompt enters the pool in ``policy.prefill_chunk``-token
chunks, one per engine tick (``ActiveSeq.prefill_progress`` tracks the
resident prefix). Each chunk runs the prefill-with-cache forward
(``Model.prefill_chunk_paged``): its roped K/V are scattered into the
sequence's pages — quantize-on-write on quantized pools — and its
attention walks the page table itself, reading the resident prefix plus
the chunk (causal within the chunk; kernels/paged_attention.py's
``paged_prefill_fwd`` on TPU, the pure-JAX walk elsewhere — the dense
chronological prompt KV view is never materialized, asserted on the
jaxpr). Chunk states per sequence:

    queued -> chunk-pending (admitted; 0 < prefill_progress < prompt,
              holds a batch slot, excluded from the decode batch)
           -> decode-ready (final chunk landed: the last real prompt row
              is unembedded, the first token sampled)
           -> finished / preempted (a mid-prefill victim is requeued at
              its chunk boundary and simply restarts the prompt at
              re-admission — prefill is deterministic, so resumption is
              token-identical)

``prefill_stall_factor`` is therefore a **per-tick** stall budget: the
admission policy sizes ``prefill_chunk`` as the largest chunk whose
prefill-with-cache latency (priced at worst-case resident context) stays
within ``prefill_stall_factor * decode_slo_s``, so a long prompt costs
more ticks — never a longer stall of resident decodes. Whole-prompt
bucketed prefill (``chunked_prefill=False``, one forward padded to the
chunk quantum) is kept as the pre-chunking baseline; greedy outputs are
identical either way (asserted across chunk sizes, page sizes, GQA,
windows, and quantized pools in tests/test_chunked_prefill.py, with the
stall win measured by the long-prompt bench and enforced by the CI
bench-gate).

The scheduler packs active sequences into a fixed-width batch; a decode
tick calls ``Model.decode_step_paged`` with:

    page_table : (B, max_pages) int32 — physical page of logical block i;
                 unused tails (and idle batch slots) point at the scratch
                 page 0, which is never allocated to a request
    positions  : (B,) int32 — per-sequence absolute position, so every slot
                 can be at a different decode depth (continuous batching)

Token ``pos`` of sequence ``b`` lives at page ``page_table[b, pos // page]``
slot ``pos % page``. Attention walks the page table block-by-block — the
Pallas paged-attention kernel (kernels/paged_attention.py) on TPU, its
pure-JAX block-walk twin (kernels/ref.py) elsewhere — with local-window
layers trimming the walk to their window; the dense chronological
(B, max_pages*page_size, K, hd) KV view is never materialized. RoPE is
applied at cache-write time with absolute positions, and the sequential
`launch.serve.generate` baseline decodes through the same walk over an
identity page table, so the engine's greedy outputs — across batching,
growth, and preemption — are token-identical to it (asserted in
tests/test_engine.py; the walk itself is validated against the dense
oracle in tests/test_kernels.py).

KV-cache quantization (serving/kvquant): ``AdmissionPolicy.kv_bits``
selects a HAQ-searched per-sub-layer bit policy for the pool itself —
pages stored int8/int4 (packed along head_dim) with per-page-slot per-head
fp32 scale tiles, quantize-on-write in both writers, and dequantization
fused into the paged-attention block walk. ``kv_bytes_per_token`` and page
sizing are bit-policy-aware, so the same HBM budget holds 2-4x the pages
and admission fits correspondingly more resident sequences; the fp pool
remains the token-exact baseline (quantized drift is bounded and measured,
see kvquant.drift).

On models whose every attention layer is local (sliding-window), pages
wholly behind the window are released back to the allocator as decode
advances (``Scheduler.trim_window``; freed slots ride along in the page
table as scratch-page placeholders the walk never reads).

SPMD serving (``Engine(mesh=...)``, serving/engine/sharded.py)
--------------------------------------------------------------
The engine runs over a ("data", "model") device mesh with every jitted
tick (decode, chunk prefill, whole-prompt prefill, pool span-writer)
shard_map'd. Per-device layout:

    sharded over ``model`` (size N):
        pool["sub{j}"]["k"|"v"]      (G, num_pages, page, K/N, hd)
        quant pools: both the int codes and the fp32 scale tiles split
        the same way — per-device page bytes really drop Nx, which is how
        ``derive_policy(mesh_model=N)`` finds ~Nx the pool capacity (and
        resident sequences) in the same per-device HBM
        wq/wk/wv (heads dims), FFN up/gate (d_ff dim): used as local
        slices — these matmuls are output-dim-sharded, so each device
        computes an identical slice of the identical computation
    sharded at rest, all-gathered at use (FSDP-style):
        every other param (embed table, attn out-proj, FFN down-proj,
        MoE experts, norms) — a contraction-sharded matmul would need a
        partial-sum all-reduce, which is not bit-stable, so the inputs
        are gathered (pure data movement) and the contraction runs whole
    replicated (host-owned, never sharded):
        page table, positions, tokens, logits — and ALL scheduler state:
        admission, growth, preemption, window-trim, and chunk accounting
        run on the host exactly as on one device; one logical page id
        covers every shard's kv-head slice of that page

The ``data`` axis is at-rest param FSDP only (batch-sharding the decode
tick is the async-host-loop follow-on). Exactness contract: kv_heads must
divide the model axis (page slots stay whole so the online softmax keeps
its 1-device reduction order), and greedy outputs on any mesh are
bit-identical to the 1-device engine across fp/int8/HAQ-mixed pools,
chunked prefill, GQA, windows, and forced preemption — asserted in
tests/test_sharded_engine.py and gated in CI (multi-device job +
scripts/check_bench_regression.py sharded floors).

Observability (serving/telemetry)
---------------------------------
Every engine owns a `Telemetry` recorder (in-memory, jax-free, no-op
export sink by default — the disabled path costs a few dataclass appends
per tick, and greedy outputs are untouched). Two event streams:

**Tick events** — one per jitted dispatch, ``kind`` in {``prefill``,
``chunk``, ``decode``}::

    TickEvent(kind, step, t_start, measured_s, predicted_s,
              batch, padded_batch, q_len, tokens, rids, admitted,
              preempted, pages_allocated/freed/trimmed,
              queue_depth, pool_free, pool_allocated, tags)

``measured_s`` is fenced wall clock (the engine blocks on the dispatch's
outputs before stopping the timer, so async jit dispatch is never billed
as compute); ``predicted_s`` is the ``admission.step_latency`` roofline
for the same shape, priced at the *padded* jit batch. Page counters are
deltas since the previous tick event. Under a mesh, ``tags`` carries the
shard layout (``mesh_model``/``mesh_data``/``mesh_devices``).

**Sequence spans** — per-rid lifecycle edges, scheduler-owned on the
queue side and engine-owned on the compute side::

    enqueue -> admit -> chunk* -> first_token
            -> (preempt -> requeue -> admit -> ...)* -> finish -> release

Spans yield real TTFT / queue-wait / stall; ``Engine.stall_log`` and
``Engine.first_token_s`` survive as thin views over them (a preempted
request keeps its first served token's TTFT).

The metrics registry (``engine.telemetry.metrics``) rolls both streams
into counters/gauges/histograms: ``ticks.*``, ``tokens.*``,
``pool.free`` (min = low-water mark), ``pool.occupancy`` /
``.fragmentation``, ``queue.depth``, ``preemptions``,
``jit.*.hits/misses/cache_size`` (steady-state decode must not
retrace), ``tick.*.measured_s`` / ``.rel_err`` histograms.

Exports: ``telemetry.write_chrome_trace(engine.telemetry, path)`` emits
Chrome trace-event JSON — open it at https://ui.perfetto.dev (or
chrome://tracing): tick slices by kind on the engine track, pool/queue
counter tracks, one async span per request. ``--trace-out`` on
launch/serve.py and benchmarks/bench_engine_throughput.py does this
from the CLI (the CI engine-smoke job uploads the bench's trace as an
artifact). ``telemetry.summarize`` prints a text rollup, and
``telemetry.calibrate(engine.telemetry.ticks)`` fits measured vs
predicted per (kind, batch, q_len) — the per-kind scale factors
`core/hardware_model`'s roofline needs to match this host, feeding the
ROADMAP's serving-stack autotuner.

Autotuning (serving/autotune)
-----------------------------
The knobs above — page size, prefill chunk, expected occupancy, KV-bit
policy, mesh split, batch cap — form a typed config space
(`autotune.ConfigSpace`), and the serving-stack autotuner searches it the
way the paper searches bit policies:

1. **calibrate** — serve a short warmup trace with the hand-picked
   default; ``telemetry.calibrate(...).scale_lookup()`` fits per-(kind,
   batch, q_len) scale factors between the roofline's ``predicted_s``
   and the fenced ``measured_s`` on THIS host.
2. **search** — DDPG (`core/rl/ddpg.py`, the AMC/HAQ agent) plus a
   seeded evolutionary baseline walk the space, scored by the
   scale-corrected ``admission.step_latency`` (`autotune.Objective`;
   thousands of candidates per second, deterministic per seed). Kinds
   with no calibration fall back to the raw roofline with a logged
   warning — never silent zeros or a made-up 1.0.
3. **validate** — the top-k candidates are re-measured on the real
   engine next to the default; the *measured* best wins (ties ship the
   default), with the Spearman predicted-vs-measured rank correlation
   reported.
4. **emit** — the winner serializes as a per-hardware JSON config;
   ``launch/serve.py --autotune N --autotune-out f.json`` writes it,
   ``--serving-config f.json`` loads it back, and
   ``Engine(roofline_scales=...)`` threads the calibration into the
   telemetry predictions of the tuned engine.

Re-fit on a new host by simply re-running ``--autotune`` there: the
warmup trace is the calibration. CI's autotune-smoke lane runs a
32-candidate search on the 4-request trace and gates that the searched
config's measured decode tok/s never falls below 0.95x the default
(scripts/check_bench_regression.py, ``autotune`` floors); nightly runs
the full budget.

Modules: `pool` (page allocator + device pool + bounded jit caches +
span-capable prefill writer), `scheduler` (FIFO admission / growth /
preemption / eviction / window-trim / prefill-progress bookkeeping),
`admission` (roofline-derived policy, expected-footprint batch sizing,
KV-bit-aware page sizing, per-tick chunk sizing, mesh-aware per-shard
sizing), `engine` (the host loop tying them to the model), `sharded`
(the SPMD machinery above); the KV quantization subsystem itself lives
in `serving/kvquant`.
"""
from repro.serving.engine.admission import AdmissionPolicy, derive_policy
from repro.serving.engine.engine import Engine
from repro.serving.engine.pool import PageAllocator, PagedKVPool
from repro.serving.engine.scheduler import Request, Scheduler

__all__ = ["AdmissionPolicy", "derive_policy", "Engine", "PageAllocator",
           "PagedKVPool", "Request", "Scheduler"]
