"""Continuous-batching scheduler: FIFO admission, dynamic page growth,
preemption, eviction, backfill.

Pure host-side bookkeeping (no jax) so the policy is unit-testable without
a model. The scheduler owns batch slots and, via the page allocator, KV
pages; the engine owns the device arrays.

Pages are allocated **lazily**: admission reserves only the pages the
prompt (plus the first generated token) needs, and a sequence grows
page-by-page as decode crosses block boundaries (``ensure_capacity``).
When the pool is exhausted mid-growth, the **youngest** active sequence is
preempted — its pages are freed and it is requeued at the FIFO front with
its generated tokens folded into the prompt (recompute-style preemption, so
its next admission re-prefills the extended prompt and resumes exactly
where it stopped). Preempting youngest-first keeps the oldest sequences
draining, so the loop makes progress and admission stays starvation-free.
``reserve_upfront=True`` restores the legacy worst-case policy — every page
a request could ever need (``ceil((prompt + max_new) / page_size)``)
reserved at admission — kept as the conservative mode and the benchmark
baseline.

Head-of-line FIFO: if the front request doesn't fit, we wait for an
eviction rather than skip it (starvation-free).

Under the SPMD engine (serving/engine/sharded.py) every bit of this state
— queue, slots, page lists, births, prefill progress — stays host-side and
device-count-agnostic: a physical page id names the same logical page on
every shard (each holds a 1/N kv-head slice of it), so admission, growth,
preemption, window-trim, and chunk accounting run unchanged on any mesh.

The scheduler owns the queue-side edges of each request's telemetry span
(serving/telemetry): ``enqueue`` at submit, ``admit`` on slot grant,
``preempt``/``requeue`` on a recompute preemption, ``release`` at
eviction. The engine adds the compute-side edges (``chunk``,
``first_token``, ``finish``). Both write into the same per-engine
`Telemetry` recorder; a standalone scheduler gets its own.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine.pool import PageAllocator
from repro.serving.telemetry import Telemetry


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32 token ids
    max_new: int                 # tokens to generate (>= 1)
    eos_id: Optional[int] = None
    arrival: float = 0.0         # seconds since trace start


@dataclasses.dataclass(eq=False)
class ActiveSeq:
    req: Request
    slot: int
    pages: List[int]
    birth: int = 0               # admission order (preemption picks max)
    pos: int = 0                 # tokens currently cached
    prefill_progress: int = 0    # prompt tokens resident in the pool
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def last_token(self) -> int:
        return self.generated[-1]

    @property
    def prefill_done(self) -> bool:
        """True once the whole prompt is resident (and the first token
        sampled) — chunk-pending sequences stay out of the decode batch."""
        return self.prefill_progress >= len(self.req.prompt)

    def is_done(self) -> bool:
        if len(self.generated) >= self.req.max_new:
            return True
        eos = self.req.eos_id
        return eos is not None and self.generated and \
            self.generated[-1] == eos


class Scheduler:
    def __init__(self, allocator: PageAllocator, max_batch: int,
                 max_model_len: int, *, reserve_upfront: bool = False,
                 telemetry: Optional[Telemetry] = None):
        self.allocator = allocator
        self.max_batch = max_batch
        self.max_model_len = max_model_len
        self.reserve_upfront = reserve_upfront
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.queue: deque = deque()
        self.active: Dict[int, ActiveSeq] = {}     # slot -> seq
        self._free_slots = list(reversed(range(max_batch)))
        self._births = 0
        self.num_preempted = 0

    # ---------------------------------------------------------- lifecycle --
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.max_model_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"max_model_len={self.max_model_len}")
        self.queue.append(req)
        self.telemetry.seq_event(req.rid, "enqueue",
                                 prompt=len(req.prompt), max_new=req.max_new,
                                 queue_depth=len(self.queue))

    def admit(self, now: float = float("inf")) -> List[ActiveSeq]:
        """Admit FIFO-front requests while a batch slot and enough pages are
        available — the prompt's pages plus one decode slot (and, while
        other sequences are in flight, one free page of growth headroom) by
        default; the full worst-case lifetime with ``reserve_upfront``.
        Returns newly admitted sequences (prefill still pending — the
        engine runs it)."""
        admitted = []
        while self.queue and self._free_slots:
            req = self.queue[0]
            if req.arrival > now:
                break
            tokens = len(req.prompt) + (req.max_new if self.reserve_upfront
                                        else 1)
            n = self.allocator.pages_for(tokens)
            if not self.reserve_upfront and self.active \
                    and self.allocator.num_free < n + 1:
                # growth watermark: admitting into the pool's last pages
                # invites paying a full prefill only to be preempted by an
                # older sequence's very next page boundary — leave one page
                # of headroom while anything else is in flight.
                break
            pages = self.allocator.alloc(n)
            if pages is None:
                break                       # wait for an eviction (FIFO)
            self.queue.popleft()
            slot = self._free_slots.pop()
            seq = ActiveSeq(req=req, slot=slot, pages=pages,
                            birth=self._births)
            self._births += 1
            self.active[slot] = seq
            admitted.append(seq)
            self.telemetry.seq_event(req.rid, "admit", slot=slot,
                                     pages=len(pages),
                                     queue_depth=len(self.queue))
        return admitted

    def ensure_capacity(self, seq: ActiveSeq) -> bool:
        """Grow ``seq`` page-by-page until it can cache the token at
        ``seq.pos``. False if the pool is exhausted (caller preempts)."""
        needed = self.allocator.pages_for(seq.pos + 1)
        while len(seq.pages) < needed:
            got = self.allocator.alloc(1)
            if got is None:
                return False
            seq.pages.extend(got)
        return True

    def trim_window(self, seq: ActiveSeq, window: int) -> int:
        """Free the pages of logical blocks wholly behind ``seq``'s sliding
        window (every slot at kpos <= seq.pos - window, dead for the query
        at seq.pos and every later one) — the ROADMAP's "trim the pages
        themselves" item. Only valid when EVERY attention layer is local
        (pages are shared across layers; one global layer pins the full
        history — the engine checks this once at construction).

        Freed slots stay in ``seq.pages`` as logical-block placeholders
        (page 0, the scratch sentinel the page-table tails already use):
        the walk's per-sequence lower bound ``(pos - window + 1) // page``
        never reads them, and release/preempt skip them. Returns the number
        of pages released."""
        page = self.allocator.page_size
        lo = max((seq.pos - window + 1) // page, 0)
        dead = [p for p in seq.pages[:lo] if p != 0]
        if dead:
            self.allocator.free(dead)
            seq.pages[:lo] = [0] * lo
        return len(dead)

    def decode_ready(self) -> List[ActiveSeq]:
        """Active sequences eligible for the decode batch: prompt fully
        resident in the pool. Chunk-pending sequences keep their batch
        slot but ride no decode tick until their final chunk lands."""
        return [s for s in self.active.values() if s.prefill_done]

    def prefill_pending(self) -> List[ActiveSeq]:
        """Active sequences still owing prompt chunks, admission order —
        the engine runs at most one chunk per tick for each."""
        return sorted((s for s in self.active.values()
                       if not s.prefill_done), key=lambda s: s.birth)

    def youngest_active(self) -> Optional[ActiveSeq]:
        """The preemption victim candidate: the most recently admitted
        active sequence. Pages always flow from younger to older — a
        growing sequence may preempt the youngest, and if it *is* the
        youngest it yields (self-preempts) rather than stalling an older
        sequence — so the FIFO head keeps draining."""
        if not self.active:
            return None
        return max(self.active.values(), key=lambda s: s.birth)

    def preempt(self, seq: ActiveSeq) -> None:
        """Free ``seq``'s slot and pages and requeue it at the FIFO front as
        a prompt-extension: the tokens it already generated become part of
        the prompt, so re-admission re-prefills them (recompute) and greedy
        outputs are unchanged. The caller's Request object is left intact —
        the extension rides a fresh Request with the same rid. (Sampled
        decode re-draws its RNG keys from the new generation offsets after
        a preemption.)

        A mid-prefill victim (prefill_progress < prompt, nothing generated
        yet) is only ever preempted at a chunk boundary — the engine runs
        chunks between scheduler phases — and its partially written pages
        are freed with the rest: re-admission restarts the prompt from
        chunk 0, so resumption is trivially token-identical (prefill is
        deterministic and the fresh ActiveSeq's prefill_progress is 0)."""
        del self.active[seq.slot]
        self.allocator.free([p for p in seq.pages if p != 0])
        self._free_slots.append(seq.slot)
        assert seq.req.max_new > len(seq.generated), \
            "done sequences are evicted, not preempted"
        resumed = dataclasses.replace(
            seq.req,
            prompt=np.concatenate([np.asarray(seq.req.prompt, np.int32),
                                   np.asarray(seq.generated, np.int32)]),
            max_new=seq.req.max_new - len(seq.generated))
        self.queue.appendleft(resumed)
        self.num_preempted += 1
        self.telemetry.seq_event(seq.req.rid, "preempt",
                                 generated=len(seq.generated),
                                 pages_freed=sum(p != 0 for p in seq.pages))
        self.telemetry.seq_event(seq.req.rid, "requeue",
                                 prompt=len(resumed.prompt),
                                 max_new=resumed.max_new)

    def release(self, seq: ActiveSeq) -> None:
        """Evict a finished sequence: free its pages and batch slot so the
        next admit() can backfill mid-flight (window-trimmed blocks are
        already free and ride along as page-0 placeholders)."""
        del self.active[seq.slot]
        self.allocator.free([p for p in seq.pages if p != 0])
        self._free_slots.append(seq.slot)
        self.telemetry.seq_event(seq.req.rid, "release",
                                 generated=len(seq.generated))

    # -------------------------------------------------------------- state --
    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    def has_work(self) -> bool:
        return bool(self.queue or self.active)
