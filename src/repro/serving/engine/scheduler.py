"""Continuous-batching scheduler: FIFO admission, eviction, backfill.

Pure host-side bookkeeping (no jax) so the policy is unit-testable without
a model. The scheduler owns batch slots and, via the page allocator, KV
pages; the engine owns the device arrays.

Admission reserves every page a request can ever need
(``ceil((prompt + max_new) / page_size)``) up front, so an admitted
sequence can never OOM mid-flight and eviction is only ever voluntary
(finished / EOS). Head-of-line FIFO: if the front request doesn't fit, we
wait for an eviction rather than skip it (starvation-free). Dynamic page
allocation with preemption is an open item (ROADMAP).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine.pool import PageAllocator


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32 token ids
    max_new: int                 # tokens to generate (>= 1)
    eos_id: Optional[int] = None
    arrival: float = 0.0         # seconds since trace start


@dataclasses.dataclass(eq=False)
class ActiveSeq:
    req: Request
    slot: int
    pages: List[int]
    pos: int = 0                 # tokens currently cached
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def last_token(self) -> int:
        return self.generated[-1]

    def is_done(self) -> bool:
        if len(self.generated) >= self.req.max_new:
            return True
        eos = self.req.eos_id
        return eos is not None and self.generated and \
            self.generated[-1] == eos


class Scheduler:
    def __init__(self, allocator: PageAllocator, max_batch: int,
                 max_model_len: int):
        self.allocator = allocator
        self.max_batch = max_batch
        self.max_model_len = max_model_len
        self.queue: deque = deque()
        self.active: Dict[int, ActiveSeq] = {}     # slot -> seq
        self._free_slots = list(reversed(range(max_batch)))

    # ---------------------------------------------------------- lifecycle --
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.max_model_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"max_model_len={self.max_model_len}")
        self.queue.append(req)

    def admit(self, now: float = float("inf")) -> List[ActiveSeq]:
        """Admit FIFO-front requests while a batch slot and enough pages for
        the request's full lifetime are available. Returns newly admitted
        sequences (prefill still pending — the engine runs it)."""
        admitted = []
        while self.queue and self._free_slots:
            req = self.queue[0]
            if req.arrival > now:
                break
            n = self.allocator.pages_for(len(req.prompt) + req.max_new)
            pages = self.allocator.alloc(n)
            if pages is None:
                break                       # wait for an eviction (FIFO)
            self.queue.popleft()
            slot = self._free_slots.pop()
            seq = ActiveSeq(req=req, slot=slot, pages=pages)
            self.active[slot] = seq
            admitted.append(seq)
        return admitted

    def release(self, seq: ActiveSeq) -> None:
        """Evict a finished sequence: free its pages and batch slot so the
        next admit() can backfill mid-flight."""
        del self.active[seq.slot]
        self.allocator.free(seq.pages)
        self._free_slots.append(seq.slot)

    # -------------------------------------------------------------- state --
    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    def has_work(self) -> bool:
        return bool(self.queue or self.active)
