"""Validate searched configs against the real engine.

The calibrated roofline ranks thousands of candidates; the top few are
then *measured* — a real `Engine` built from each candidate's policy,
warmed on the exact trace and re-timed (jit compiles excluded), exactly
the methodology of benchmarks/bench_engine_throughput.py. The winner is
the best MEASURED candidate, and `spearman` reports how well the
calibrated objective predicted the measured ranking — the paper's
predicted-vs-measured fidelity number, recorded in the bench's
``autotune`` section.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.autotune.objective import ScoredCandidate
from repro.serving.autotune.space import ConfigSpace
from repro.serving.engine import Engine


@dataclasses.dataclass
class MeasuredCandidate:
    scored: ScoredCandidate
    decode_tok_s: float
    ttft_p50_s: float
    wall_s: float
    decode_ticks: int
    preemptions: int

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["scored"] = self.scored.as_dict()
        return d


def measure_candidate(
    model,
    params,
    space: ConfigSpace,
    scored: ScoredCandidate,
    reqs,
    *,
    roofline_scales=None,
    engine: Optional[Engine] = None,
) -> Optional[MeasuredCandidate]:
    """Serve ``reqs`` through an engine built from the candidate; warm
    on the exact trace, then re-time the same instance. Returns None for
    candidates this host cannot run (mesh split wider than the visible
    devices). Pass ``engine`` to reuse an already-built engine (the
    default config's calibration engine)."""
    import jax

    c = scored.config
    if engine is None:
        if c.mesh_model > jax.device_count():
            return None
        mesh = None
        if c.mesh_model > 1:
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(model=c.mesh_model, data=1)
        policy = space.to_policy(c)
        engine = Engine(
            model,
            params,
            policy,
            mesh=mesh,
            roofline_scales=roofline_scales,
        )
    engine.run(reqs, realtime=False)  # warm: jit compiles off the clock
    engine.reset_stats()
    t0 = time.monotonic()
    engine.run(reqs, realtime=False)
    dt = time.monotonic() - t0
    stats = engine.stats
    ttft = sorted(engine.first_token_s.values())
    return MeasuredCandidate(
        scored=scored,
        decode_tok_s=stats["decode_tokens"] / dt if dt > 0 else 0.0,
        ttft_p50_s=float(np.median(ttft)) if ttft else 0.0,
        wall_s=dt,
        decode_ticks=stats["decode_ticks"],
        preemptions=stats["preemptions"],
    )


def validate_candidates(
    model,
    params,
    space: ConfigSpace,
    scored: List[ScoredCandidate],
    reqs,
    *,
    roofline_scales=None,
) -> List[MeasuredCandidate]:
    """Measure each candidate (preserving order, skipping unmeasurable
    ones); duplicate configs are measured once."""
    out: List[MeasuredCandidate] = []
    seen = set()
    for s in scored:
        if s.config in seen:
            continue
        seen.add(s.config)
        m = measure_candidate(
            model,
            params,
            space,
            s,
            reqs,
            roofline_scales=roofline_scales,
        )
        if m is not None:
            out.append(m)
    return out


def spearman(xs, ys) -> Optional[float]:
    """Spearman rank correlation (average ranks on ties); None when
    fewer than 3 points or either side is constant — a correlation from
    2 points is a coin flip, and NaN must never reach the bench JSON."""
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    if xs.size != ys.size or xs.size < 3:
        return None
    if np.ptp(xs) == 0.0 or np.ptp(ys) == 0.0:
        return None

    def ranks(v):
        order = np.argsort(v, kind="stable")
        r = np.empty_like(v)
        r[order] = np.arange(v.size, dtype=np.float64)
        # average tied ranks
        for val in np.unique(v):
            m = v == val
            r[m] = r[m].mean()
        return r

    rx, ry = ranks(xs), ranks(ys)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = float(np.sqrt((rx * rx).sum() * (ry * ry).sum()))
    if denom == 0.0:
        return None
    return float((rx * ry).sum() / denom)
