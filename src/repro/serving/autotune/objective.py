"""Calibrated-roofline objective: the autotuner's fast feedback signal.

The paper's loop (AMC/HAQ) searches on a cheap signal and trusts it only
as far as its validation against the device says it deserves. Here the
cheap signal is `admission.step_latency` — the same roofline that sizes
the engine — and the validation is `telemetry.calibrate`: a short warmup
trace on the target host fits per-(kind, batch, q_len) scale factors
between the roofline's prediction and the fenced measured tick latency,
exported as a `ScaleLookup`. Scoring a candidate costs two analytic
latency queries, so thousands of configs are searched per second; the
top candidates are then re-measured for real (autotune/validate.py).

Fallback contract (the unknown-``hw_name`` fix): when no calibration
scale exists for a tick kind — the warmup engine ran a hardware target
not in ``HARDWARES`` so every ``predicted_s`` was 0.0 and `calibrate`
fitted nothing, or no warmup ran at all — the objective falls back to
the RAW roofline with a logged warning, once per kind. It never scores
zeros (the pre-fix behaviour: `RooflinePredictor` answers 0.0 for an
unknown target, and an objective built on it would rank every candidate
equal at -inf throughput) and never invents a silent 1.0.
"""

from __future__ import annotations

import dataclasses
import logging
import math
from typing import Dict, Optional, Tuple

from repro.serving.autotune.space import ConfigSpace, ServingConfig
from repro.serving.engine.admission import step_latency

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    """One candidate with its calibrated-roofline score. Inadmissible
    candidates carry ``score=-inf`` and their constraint violations."""

    config: ServingConfig
    score: float
    admissible: bool
    violations: Tuple[str, ...] = ()
    pred_decode_tok_s: float = 0.0
    pred_ttft_s: float = 0.0
    pred_decode_tick_s: float = 0.0
    pred_chunk_tick_s: float = 0.0
    calibrated: bool = False
    max_batch: int = 0
    num_pages: int = 0

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["config"] = self.config.as_dict()
        d["violations"] = list(self.violations)
        return d


class Objective:
    """Score = calibrated predicted aggregate decode tok/s, softly
    discounted when predicted TTFT overshoots ``ttft_slo_s`` (None
    disables the discount — pure decode throughput).

    * decode: the policy's (capped) max_batch tokens per tick over the
      scale-corrected decode-tick roofline at worst-case context;
    * TTFT: ``ceil(prompt_len / chunk)`` chunk ticks, each at the
      scale-corrected prefill-with-cache roofline (matching the chunked
      engine: one chunk per tick, decode interleaving ignored).

    ``scales`` is a `telemetry.ScaleLookup` (or None). Results are
    memoized per candidate — searchers revisit configs freely.
    """

    def __init__(
        self,
        space: ConfigSpace,
        *,
        scales=None,
        prompt_len: int = 32,
        ttft_slo_s: Optional[float] = None,
    ):
        self.space = space
        self.scales = scales
        self.prompt_len = max(int(prompt_len), 1)
        self.ttft_slo_s = ttft_slo_s
        self._warned: set = set()
        self._memo: Dict[ServingConfig, ScoredCandidate] = {}

    def _scale(self, kind: str, batch: int, q_len: int):
        """(scale, calibrated?) — raw-roofline fallback logs once."""
        s = (
            self.scales.scale(kind, batch, q_len)
            if self.scales is not None
            else None
        )
        if s is not None:
            return float(s), True
        if kind not in self._warned:
            self._warned.add(kind)
            log.warning(
                "autotune: no calibration scale for kind=%r on %s — "
                "scoring on the RAW roofline (fit scales on this host "
                "with telemetry.calibrate over a warmup trace)",
                kind,
                self.space.hw.name,
            )
        return 1.0, False

    def __call__(self, c: ServingConfig) -> ScoredCandidate:
        got = self._memo.get(c)
        if got is not None:
            return got
        sc = self._score(c)
        self._memo[c] = sc
        return sc

    def _score(self, c: ServingConfig) -> ScoredCandidate:
        viols = self.space.violations(c)
        if viols:
            return ScoredCandidate(
                config=c,
                score=float("-inf"),
                admissible=False,
                violations=viols,
            )
        space = self.space
        policy = space.to_policy(c)
        B = policy.max_batch
        raw_decode = step_latency(
            space.cfg,
            B,
            1,
            space.max_model_len,
            space.hw,
            w_bits=policy.quant_bits,
            kv_bits=policy.kv_bits,
            mesh_model=policy.mesh_model,
        )
        s_decode, cal_d = self._scale("decode", B, 1)
        decode_tick = s_decode * raw_decode
        tok_s = B / decode_tick if decode_tick > 0.0 else 0.0

        chunk = policy.prefill_chunk
        raw_chunk = step_latency(
            space.cfg,
            1,
            chunk,
            space.max_model_len,
            space.hw,
            w_bits=policy.quant_bits,
            mesh_model=policy.mesh_model,
        )
        s_chunk, cal_c = self._scale("chunk", 1, chunk)
        chunk_tick = s_chunk * raw_chunk
        ttft = math.ceil(self.prompt_len / chunk) * chunk_tick

        score = tok_s
        if self.ttft_slo_s:
            score /= 1.0 + max(0.0, ttft / self.ttft_slo_s - 1.0)
        return ScoredCandidate(
            config=c,
            score=score,
            admissible=True,
            pred_decode_tok_s=tok_s,
            pred_ttft_s=ttft,
            pred_decode_tick_s=decode_tick,
            pred_chunk_tick_s=chunk_tick,
            calibrated=cal_d and cal_c,
            max_batch=B,
            num_pages=policy.num_pages,
        )
