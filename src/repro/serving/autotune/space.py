"""Typed config space for the serving-stack autotuner.

A `ServingConfig` is one point in the engine's hand-tunable knob space —
page size, prefill chunk, expected occupancy, KV-bit policy, mesh split,
and the in-flight batch cap. `ConfigSpace` owns the per-dimension choice
lists (filtered to what the model/hardware pair admits: chunks never
exceed the padding bucket, mesh splits must divide ``kv_heads``),
encodes/decodes candidates to the unit hypercube the DDPG agent acts in,
and lowers a candidate to a full `AdmissionPolicy` via the same
`derive_policy` roofline the engine serves with — so a searched config
is, by construction, the same object a hand-picked one is.

Per-hardware configs serialize to JSON (`config_record` /
`save_serving_config` / `load_serving_config`): the artifact the search
emits and ``launch/serve.py --serving-config`` loads back.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware_model import Hardware
from repro.serving.engine.admission import AdmissionPolicy, derive_policy

# symbolic KV-pool policies; resolved to derive_policy(kv_bits=...) values
# by ConfigSpace.kv_bits_for (the "haq" tuple is the deterministic
# sensitivity-gated back-off from serving/kvquant, episodes=0 — no search
# inside the search)
KV_POLICIES = ("fp16", "int8", "haq")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """One candidate: the engine knobs the autotuner is allowed to move.

    Everything else in `AdmissionPolicy` (num_pages, max_batch, quant
    bits) stays *derived* — the roofline answers those once these are
    fixed, exactly as it does for the hand-picked defaults.
    """

    page_size: int
    prefill_chunk: int
    expected_occupancy: float
    kv_policy: str
    mesh_model: int
    max_batch_cap: int

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ServingConfig":
        return cls(
            page_size=int(d["page_size"]),
            prefill_chunk=int(d["prefill_chunk"]),
            expected_occupancy=float(d["expected_occupancy"]),
            kv_policy=str(d["kv_policy"]),
            mesh_model=int(d["mesh_model"]),
            max_batch_cap=int(d["max_batch_cap"]),
        )

    def sort_key(self) -> Tuple:
        """Total order for deterministic tie-breaks in search rankings."""
        return dataclasses.astuple(self)


class ConfigSpace:
    """The discrete candidate space over one (model config, hardware,
    max_model_len) serving target.

    ``max_devices`` bounds the mesh dimension (1 on a single-device
    host, so the dimension collapses to its only legal choice);
    ``max_batch_cap`` bounds the batch-cap dimension (the bench/serve
    CLI cap, not the roofline's — `to_policy` takes the min of both).
    """

    def __init__(
        self,
        cfg,
        hw: Hardware,
        *,
        max_model_len: int,
        max_devices: int = 1,
        max_batch_cap: int = 8,
        param_bytes: Optional[int] = None,
        page_sizes: Sequence[int] = (8, 16, 32, 64),
        prefill_chunks: Sequence[int] = (16, 32, 64, 128, 256, 512),
        occupancies: Sequence[float] = (0.25, 0.5, 0.75, 1.0),
        kv_policies: Sequence[str] = KV_POLICIES,
    ):
        self.cfg = cfg
        self.hw = hw
        self.max_model_len = int(max_model_len)
        self.max_devices = int(max_devices)
        self.max_batch_cap = int(max_batch_cap)
        self.param_bytes = param_bytes
        unknown = [k for k in kv_policies if k not in KV_POLICIES]
        if unknown:
            raise ValueError(f"unknown kv policies {unknown}")
        page_sizes = tuple(
            p for p in sorted(set(page_sizes)) if 0 < p <= max_model_len
        )
        chunks = tuple(
            c
            for c in sorted(set(prefill_chunks))
            if 0 < c <= max_model_len  # chunk <= bucket, by construction
        )
        meshes = tuple(
            m
            for m in (1, 2, 4, 8, 16)
            if m <= self.max_devices and cfg.num_kv_heads % m == 0
        )
        caps = tuple(
            b for b in (1, 2, 4, 8, 16, 32, 64) if b <= self.max_batch_cap
        )
        if self.max_batch_cap not in caps:
            caps = caps + (self.max_batch_cap,)
        if not (page_sizes and chunks and meshes and caps):
            raise ValueError(
                f"empty config space for {cfg.name} @ "
                f"max_model_len={max_model_len}"
            )
        # ordered knob dimensions: (name, choice tuple). This IS the
        # encoding — vectors, indices, and the DDPG walk all follow it.
        self.dims: Tuple[Tuple[str, Tuple], ...] = (
            ("page_size", page_sizes),
            ("prefill_chunk", chunks),
            ("expected_occupancy", tuple(sorted(set(occupancies)))),
            ("kv_policy", tuple(kv_policies)),
            ("mesh_model", meshes),
            ("max_batch_cap", caps),
        )
        self._kv_bits_memo: Dict[str, object] = {}

    # ------------------------------------------------------------ encoding --
    @property
    def num_dims(self) -> int:
        return len(self.dims)

    def size(self) -> int:
        n = 1
        for _, choices in self.dims:
            n *= len(choices)
        return n

    def from_indices(self, idxs: Sequence[int]) -> ServingConfig:
        vals = {}
        for (name, choices), i in zip(self.dims, idxs):
            vals[name] = choices[max(0, min(int(i), len(choices) - 1))]
        return ServingConfig(**vals)

    def indices(self, c: ServingConfig) -> List[int]:
        out = []
        for name, choices in self.dims:
            val = getattr(c, name)
            try:
                out.append(choices.index(val))
            except ValueError:
                raise ValueError(
                    f"{name}={val!r} is not a choice of this space "
                    f"(choices: {choices})"
                ) from None
        return out

    def encode(self, c: ServingConfig) -> np.ndarray:
        """Config -> unit-hypercube vector (one coordinate per knob,
        index normalized to [0, 1]; single-choice dims encode as 0)."""
        vec = []
        for (name, choices), i in zip(self.dims, self.indices(c)):
            vec.append(i / (len(choices) - 1) if len(choices) > 1 else 0.0)
        return np.asarray(vec, np.float64)

    def decode(self, vec: Sequence[float]) -> ServingConfig:
        """Unit-hypercube vector -> nearest config (rounds each
        coordinate onto its choice grid; exact inverse of `encode`)."""
        vec = np.asarray(vec, np.float64)
        if vec.shape != (self.num_dims,):
            raise ValueError(
                f"expected a {self.num_dims}-dim vector, got {vec.shape}"
            )
        idxs = []
        for (name, choices), v in zip(self.dims, vec):
            v = float(min(max(v, 0.0), 1.0))
            idxs.append(int(round(v * (len(choices) - 1))))
        return self.from_indices(idxs)

    def sample(self, rng: np.random.Generator) -> ServingConfig:
        return self.from_indices(
            [int(rng.integers(len(ch))) for _, ch in self.dims]
        )

    def default(self) -> ServingConfig:
        """The hand-picked baseline as a point of this space: page 16,
        the roofline-derived prefill chunk (snapped onto the chunk
        grid), 0.5 occupancy, the exact fp pool, no mesh split, and the
        full batch cap — the config every engine in this repo ran with
        before the autotuner existed."""
        pages = dict(self.dims)["page_size"]
        page = 16 if 16 in pages else pages[len(pages) // 2]
        chunks = dict(self.dims)["prefill_chunk"]
        try:
            derived = derive_policy(
                self.cfg,
                self.hw,
                max_model_len=self.max_model_len,
                page_size=page,
                param_bytes=self.param_bytes,
            ).prefill_chunk
        except (ValueError, NotImplementedError):
            derived = chunks[0]
        chunk = max(
            (c for c in chunks if c <= derived), default=chunks[0]
        )
        occs = dict(self.dims)["expected_occupancy"]
        occ = 0.5 if 0.5 in occs else occs[len(occs) // 2]
        kvs = dict(self.dims)["kv_policy"]
        return ServingConfig(
            page_size=page,
            prefill_chunk=chunk,
            expected_occupancy=occ,
            kv_policy="fp16" if "fp16" in kvs else kvs[0],
            mesh_model=1,
            max_batch_cap=self.max_batch_cap,
        )

    # --------------------------------------------------------- constraints --
    def kv_bits_for(self, kv_policy: str):
        """Resolve a symbolic KV policy to derive_policy's kv_bits value:
        None (bf16), 8 (uniform int8), or the deterministic
        sensitivity-gated HAQ tuple (episodes=0 back-off — local-window
        slots int4, global slots int8)."""
        if kv_policy not in self._kv_bits_memo:
            if kv_policy == "fp16":
                bits = None
            elif kv_policy == "int8":
                bits = 8
            elif kv_policy == "haq":
                from repro.serving.kvquant import search_kv_policy

                bits = search_kv_policy(
                    self.cfg,
                    self.hw,
                    max_model_len=self.max_model_len,
                    episodes=0,
                    budget_frac=0.4,
                )["bits"]
            else:
                raise ValueError(f"unknown kv policy {kv_policy!r}")
            self._kv_bits_memo[kv_policy] = bits
        return self._kv_bits_memo[kv_policy]

    def violations(self, c: ServingConfig) -> Tuple[str, ...]:
        """Constraint check; empty tuple = admissible. Cheap structural
        checks first (membership, divisibility, chunk <= bucket), then
        the HBM roofline via `derive_policy` itself — the same ValueError
        that would reject a hand-picked config rejects a searched one."""
        v = []
        for name, choices in self.dims:
            if getattr(c, name) not in choices:
                v.append(f"{name}={getattr(c, name)!r} not in {choices}")
        if v:
            return tuple(v)
        if c.prefill_chunk > self.max_model_len:
            v.append(
                f"prefill_chunk {c.prefill_chunk} exceeds the "
                f"{self.max_model_len}-token bucket"
            )
        if self.cfg.num_kv_heads % c.mesh_model:
            v.append(
                f"mesh_model={c.mesh_model} does not divide "
                f"kv_heads={self.cfg.num_kv_heads}"
            )
        if not 0.0 < c.expected_occupancy <= 1.0:
            v.append(
                f"expected_occupancy={c.expected_occupancy} not in (0, 1]"
            )
        if not v:
            try:
                self.to_policy(c)
            except (ValueError, NotImplementedError) as e:
                v.append(f"roofline-infeasible: {e}")
        return tuple(v)

    def to_policy(self, c: ServingConfig) -> AdmissionPolicy:
        """Lower a candidate to the full admission policy: derive pool
        capacity / batch / weight bits from the roofline at the
        candidate's knobs, then pin the searched chunk and cap the
        in-flight batch."""
        policy = derive_policy(
            self.cfg,
            self.hw,
            max_model_len=self.max_model_len,
            page_size=c.page_size,
            expected_occupancy=c.expected_occupancy,
            param_bytes=self.param_bytes,
            kv_bits=self.kv_bits_for(c.kv_policy),
            mesh_model=c.mesh_model,
        )
        return dataclasses.replace(
            policy,
            max_batch=max(min(policy.max_batch, c.max_batch_cap), 1),
            prefill_chunk=c.prefill_chunk,
        )


# ------------------------------------------------------------- config I/O --
CONFIG_SCHEMA = 1


def config_record(
    space: ConfigSpace, c: ServingConfig, **provenance
) -> Dict:
    """A per-hardware serving config as a JSON-serializable record: the
    knobs plus the target they were searched for and how (budget, seed,
    predicted/measured scores — whatever the caller recorded)."""
    bits = space.kv_bits_for(c.kv_policy)
    return {
        "schema": CONFIG_SCHEMA,
        "hw": space.hw.name,
        "arch": space.cfg.name,
        "max_model_len": space.max_model_len,
        "knobs": c.as_dict(),
        "kv_bits": list(bits) if isinstance(bits, tuple) else bits,
        "provenance": dict(provenance),
    }


def save_serving_config(path: str, record: Dict) -> None:
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def load_serving_config(path: str) -> Tuple[ServingConfig, Dict]:
    """Read a config JSON back; returns (knobs, full record). The caller
    owns compatibility checks (hw/arch/max_model_len match) — the record
    carries them for exactly that."""
    with open(path) as f:
        record = json.load(f)
    if record.get("schema") != CONFIG_SCHEMA:
        raise ValueError(
            f"{path}: serving-config schema "
            f"{record.get('schema')!r} != {CONFIG_SCHEMA}"
        )
    return ServingConfig.from_dict(record["knobs"]), record
