"""End-to-end autotune driver: calibrate -> search -> validate -> emit.

`autotune_serving_config` is the whole HAQ-shaped loop over the serving
stack, shared by ``launch/serve.py --autotune`` and the bench's
``autotune`` section:

  1. **calibrate** — serve a short warmup trace with the hand-picked
     default config; `telemetry.calibrate` fits the per-(kind, batch,
     q_len) measured/predicted scale factors for THIS host. The warmup's
     timed re-run doubles as the default's measured score.
  2. **search** — DDPG + evolutionary search over the `ConfigSpace`,
     scored by the scale-corrected roofline (`Objective`). Budget is
     objective evaluations; all of this is analytic and fast.
  3. **validate** — the top-k searched configs are *measured* on the
     real engine alongside the default; the winner is the best measured
     candidate (the default wins ties, so a noisy search can never ship
     a config that measured worse).
  4. **emit** — `result.record(space)` is the per-hardware JSON artifact
     (`save_serving_config`) that ``--serving-config`` loads back.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.serving.autotune.objective import Objective, ScoredCandidate
from repro.serving.autotune.search import SearchResult, search_serving_config
from repro.serving.autotune.space import ConfigSpace, config_record
from repro.serving.autotune.validate import (
    MeasuredCandidate,
    measure_candidate,
    spearman,
    validate_candidates,
)
from repro.serving.engine import Engine
from repro.serving.telemetry import ScaleLookup, calibrate


@dataclasses.dataclass
class TuneResult:
    default: MeasuredCandidate
    winner: MeasuredCandidate
    search: SearchResult
    validated: List[MeasuredCandidate]  # default first, then top-k
    scales: ScaleLookup
    rank_correlation: Optional[float]

    @property
    def searched_vs_default(self) -> float:
        base = self.default.decode_tok_s
        return self.winner.decode_tok_s / base if base > 0 else 0.0

    def record(self, space: ConfigSpace) -> Dict:
        """The winner as a per-hardware serving-config JSON record."""
        return config_record(
            space,
            self.winner.scored.config,
            budget=self.search.budget,
            seed=self.search.seed,
            method=self.search.method,
            candidates=self.search.evaluated,
            admissible=self.search.admissible,
            predicted_decode_tok_s=self.winner.scored.pred_decode_tok_s,
            measured_decode_tok_s=self.winner.decode_tok_s,
            default_decode_tok_s=self.default.decode_tok_s,
            searched_vs_default=self.searched_vs_default,
            rank_correlation=self.rank_correlation,
            calibration=self.scales.as_dict(),
        )


def autotune_serving_config(
    model,
    params,
    space: ConfigSpace,
    warmup_reqs,
    *,
    budget: int = 64,
    top_k: int = 3,
    seed: int = 0,
    method: str = "both",
    ttft_slo_s: Optional[float] = None,
    validate_reqs=None,
) -> TuneResult:
    """Run the full loop on ``warmup_reqs`` (calibration + measurement
    trace; pass ``validate_reqs`` to measure candidates on a different
    trace than the calibration warmup)."""
    validate_reqs = (
        validate_reqs if validate_reqs is not None else warmup_reqs
    )
    default_cfg = space.default()
    default_policy = space.to_policy(default_cfg)
    engine = Engine(model, params, default_policy)
    # score the default AFTER calibration so predicted/measured pairs are
    # consistent; measure it first so its ticks fit the scales
    default_measured_raw = measure_candidate(
        model,
        params,
        space,
        ScoredCandidate(
            config=default_cfg, score=0.0, admissible=True
        ),
        warmup_reqs,
        engine=engine,
    )
    scales = calibrate(engine.telemetry.ticks).scale_lookup()

    prompt_len = max(
        int(sum(len(r.prompt) for r in warmup_reqs) / len(warmup_reqs)), 1
    )
    objective = Objective(
        space,
        scales=scales,
        prompt_len=prompt_len,
        ttft_slo_s=ttft_slo_s,
    )
    result = search_serving_config(
        space, objective, budget=budget, seed=seed, method=method
    )

    default_scored = objective(default_cfg)
    default_measured = dataclasses.replace(
        default_measured_raw, scored=default_scored
    )
    top = [
        s
        for s in result.ranked
        if s.config != default_cfg
    ][: max(top_k, 1)]
    validated = [default_measured] + validate_candidates(
        model,
        params,
        space,
        top,
        validate_reqs,
        roofline_scales=scales,
    )
    # winner = best measured; max() keeps the FIRST maximum, and the
    # default is first, so ties ship the hand-picked config
    winner = max(validated, key=lambda m: m.decode_tok_s)
    corr = spearman(
        [m.scored.score for m in validated],
        [m.decode_tok_s for m in validated],
    )
    return TuneResult(
        default=default_measured,
        winner=winner,
        search=result,
        validated=validated,
        scales=scales,
        rank_correlation=corr,
    )
