"""Serving-stack autotuner: the paper's design-automation thesis aimed
at the serving engine itself.

The engine's config space — page size, prefill chunk, expected
occupancy, KV-bit policy, mesh split, batch cap — was tuned by hand
until now. This package searches it the way HAQ searches bit policies:

* `space`     — typed `ServingConfig` candidates + `ConfigSpace`
                (choices, constraints, unit-hypercube encoding,
                `to_policy` lowering via the admission roofline, and the
                per-hardware JSON config I/O);
* `objective` — the fast feedback signal: `admission.step_latency`
                corrected by per-(kind, batch, q_len) calibration scale
                factors fitted on the target host by
                `telemetry.calibrate` (raw-roofline fallback, with a
                logged warning, when no calibration exists);
* `search`    — DDPG (`core/rl/ddpg.py`, the AMC/HAQ agent) plus a
                seeded evolutionary baseline; deterministic per seed;
* `validate`  — top-k candidates re-measured on the real engine, with
                the Spearman predicted-vs-measured rank correlation;
* `tune`      — the end-to-end calibrate -> search -> validate -> emit
                loop behind ``launch/serve.py --autotune`` and the
                bench's ``autotune`` section.

The searched winner ships as a per-hardware JSON config
(``--serving-config`` loads it), and CI gates that its *measured*
decode tok/s never falls below the hand-picked default
(scripts/check_bench_regression.py, ``autotune`` floors).
"""

from repro.serving.autotune.objective import Objective, ScoredCandidate
from repro.serving.autotune.search import (
    SearchResult,
    ddpg_search,
    evolutionary_search,
    search_serving_config,
)
from repro.serving.autotune.space import (
    KV_POLICIES,
    ConfigSpace,
    ServingConfig,
    config_record,
    load_serving_config,
    save_serving_config,
)
from repro.serving.autotune.tune import TuneResult, autotune_serving_config
from repro.serving.autotune.validate import (
    MeasuredCandidate,
    measure_candidate,
    spearman,
    validate_candidates,
)

__all__ = [
    "ConfigSpace",
    "KV_POLICIES",
    "MeasuredCandidate",
    "Objective",
    "ScoredCandidate",
    "SearchResult",
    "ServingConfig",
    "TuneResult",
    "autotune_serving_config",
    "config_record",
    "ddpg_search",
    "evolutionary_search",
    "load_serving_config",
    "measure_candidate",
    "save_serving_config",
    "search_serving_config",
    "spearman",
    "validate_candidates",
]
