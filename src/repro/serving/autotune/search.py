"""Search over the serving config space: DDPG (the paper's AMC/HAQ
agent, `core/rl/ddpg.py`) plus a seeded evolutionary baseline.

Both searchers consume the calibrated-roofline `Objective` — thousands
of evaluations per second — and are deterministic under a fixed seed
(numpy Generators throughout; the DDPG actor's jax init and CPU train
steps are seed-deterministic too). ``budget`` counts objective
evaluations of *distinct* candidates; revisits hit the objective's memo
and cost nothing. `search_serving_config` is the entry point: it splits
the budget across both methods, merges, and ranks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.rl.ddpg import DDPG, DDPGConfig
from repro.serving.autotune.objective import Objective, ScoredCandidate
from repro.serving.autotune.space import ConfigSpace, ServingConfig

STATE_DIM = 4


@dataclasses.dataclass
class SearchResult:
    ranked: List[ScoredCandidate]  # admissible only, best score first
    evaluated: int  # distinct candidates scored
    admissible: int
    method: str
    seed: int
    budget: int

    @property
    def best(self) -> Optional[ScoredCandidate]:
        return self.ranked[0] if self.ranked else None


def _rank(scored: List[ScoredCandidate]) -> List[ScoredCandidate]:
    """Admissible candidates, best calibrated score first; ties broken
    on the config's total order so rankings are reproducible."""
    return sorted(
        (s for s in scored if s.admissible),
        key=lambda s: (-s.score, s.config.sort_key()),
    )


def evolutionary_search(
    space: ConfigSpace,
    objective: Objective,
    *,
    budget: int = 32,
    seed: int = 0,
    pop_size: int = 8,
    mutate_p: float = 0.35,
) -> List[ScoredCandidate]:
    """Seeded (mu + lambda)-style search: population of encoded configs,
    uniform crossover of two tournament-selected parents, per-dimension
    mutation onto a random other choice. The hand-picked default is in
    the initial population, so the best result never scores below it."""
    rng = np.random.default_rng(seed)
    seen: Dict[ServingConfig, ScoredCandidate] = {}
    limit = min(budget, space.size())

    def evaluate(c: ServingConfig) -> Optional[ScoredCandidate]:
        if c not in seen:
            if len(seen) >= limit:
                return None
            seen[c] = objective(c)
        return seen[c]

    pop = [space.default()]
    while len(pop) < pop_size and len(seen) + len(pop) <= limit:
        pop.append(space.sample(rng))
    for c in pop:
        evaluate(c)

    attempts = 0
    while len(seen) < limit and attempts < budget * 20:
        attempts += 1
        ranked = _rank(list(seen.values()))
        parents = ranked[: max(pop_size, 2)] or list(seen.values())

        def pick() -> ServingConfig:
            i = int(min(rng.integers(len(parents)),
                        rng.integers(len(parents))))
            return parents[i].config

        a, b = space.indices(pick()), space.indices(pick())
        child = [
            (a if rng.random() < 0.5 else b)[t]
            for t in range(space.num_dims)
        ]
        for t, (_, choices) in enumerate(space.dims):
            if len(choices) > 1 and rng.random() < mutate_p:
                others = [i for i in range(len(choices)) if i != child[t]]
                child[t] = int(others[int(rng.integers(len(others)))])
        evaluate(space.from_indices(child))
    return list(seen.values())


def _ddpg_state(space: ConfigSpace, t: int, prev: float) -> np.ndarray:
    return np.array(
        [
            t / max(space.num_dims - 1, 1),
            prev,
            len(space.dims[t][1]) / 8.0,
            1.0,
        ],
        np.float32,
    )


def ddpg_search(
    space: ConfigSpace,
    objective: Objective,
    *,
    budget: int = 32,
    seed: int = 0,
) -> List[ScoredCandidate]:
    """AMC/HAQ-style episodic search: one episode walks the knob
    dimensions in order, the continuous action in [0, 1] picks each
    knob's choice index, and the terminal reward is the candidate's
    score relative to the best seen so far (inadmissible = -1)."""
    agent = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=seed)
    seen: Dict[ServingConfig, ScoredCandidate] = {}
    best_score = objective(space.default()).score
    if not np.isfinite(best_score) or best_score <= 0.0:
        best_score = None
    for _ in range(budget):
        idxs: List[int] = []
        traj = []
        prev = 0.0
        for t, (_, choices) in enumerate(space.dims):
            st = _ddpg_state(space, t, prev)
            a = agent.act(st, explore=True)
            i = int(round(a * (len(choices) - 1)))
            i = max(0, min(i, len(choices) - 1))
            idxs.append(i)
            traj.append((st, a))
            prev = i / max(len(choices) - 1, 1)
        cand = space.from_indices(idxs)
        sc = seen.get(cand)
        if sc is None:
            sc = objective(cand)
            seen[cand] = sc
        if not sc.admissible:
            reward = -1.0
        elif best_score is None:
            best_score = sc.score
            reward = 1.0
        else:
            reward = float(
                np.clip(sc.score / best_score - 1.0, -1.0, 1.0)
            )
            best_score = max(best_score, sc.score)
        for t, (st, a) in enumerate(traj):
            done = t == len(traj) - 1
            s2 = (
                _ddpg_state(
                    space,
                    t + 1,
                    idxs[t] / max(len(space.dims[t][1]) - 1, 1),
                )
                if not done
                else np.zeros(STATE_DIM, np.float32)
            )
            agent.observe(st, a, reward if done else 0.0, s2, done)
        agent.end_episode()
    return list(seen.values())


def search_serving_config(
    space: ConfigSpace,
    objective: Objective,
    *,
    budget: int = 64,
    seed: int = 0,
    method: str = "both",
) -> SearchResult:
    """Run the configured searcher(s) and merge into one ranked result.

    ``method``: "evolution", "ddpg", or "both" (the default — half the
    budget each, evolution first; candidates both find are scored once
    thanks to the objective memo and deduped here)."""
    if method not in ("evolution", "ddpg", "both"):
        raise ValueError(f"unknown search method {method!r}")
    scored: Dict[ServingConfig, ScoredCandidate] = {}

    def merge(results: List[ScoredCandidate]) -> None:
        for s in results:
            scored.setdefault(s.config, s)

    if method in ("evolution", "both"):
        ev_budget = budget // 2 if method == "both" else budget
        merge(
            evolutionary_search(
                space, objective, budget=ev_budget, seed=seed
            )
        )
    if method in ("ddpg", "both"):
        dd_budget = budget - budget // 2 if method == "both" else budget
        merge(ddpg_search(space, objective, budget=dd_budget, seed=seed))

    all_scored = list(scored.values())
    ranked = _rank(all_scored)
    return SearchResult(
        ranked=ranked,
        evaluated=len(all_scored),
        admissible=len(ranked),
        method=method,
        seed=seed,
        budget=budget,
    )
