"""Quantized serving: HAQ policies as first-class serve-step parameters.

Matmul weights are STORED int8 (or int4, two-per-byte packed along the
contracting dim, key "q4") with per-tensor fp32 scales; the `dot` hook
dequantizes in the compute path. This is what the dry-run lowers for the
quantized decode cells — HBM weight bytes (the decode memory-roofline term)
drop 2x/4x vs bf16: the paper's Fig. 4 roofline move realized at pod scale.

int4 packing applies where the contracting dim is the second-to-last
(2D ffn/proj weights, MoE expert tensors); 3D attention projections clamp to
int8 (their share of decode weight bytes is small — noted in EXPERIMENTS.md).

On real TPUs the W8A16/W4A16 paths dispatch to repro.kernels.quant_matmul;
under XLA (dry-run/CPU) the dequant+einsum form has identical HBM traffic.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import default_site_of, _einsum_for
from repro.models.params import PDef

F32 = jnp.float32

_QUANT_KEYS = ("'wq'", "'wk'", "'wv'", "'wo'", "'w_in'", "'w_gate'",
               "'w_out'", "'in_proj'", "'out_proj'", "'lm_head'",
               "'fuse_in'", "'fuse_out'")
# 3D attention projections: contracting dim is not -2 -> int8 only
_NO_PACK = ("'wq'", "'wk'", "'wv'", "'wo'")


def _bits_for(keystr: str, policy: Optional[Dict[str, int]],
              default_bits: int) -> Optional[int]:
    if not any(k in keystr for k in _QUANT_KEYS):
        return None
    if policy is None:
        bits = default_bits
    else:
        site = default_site_of(keystr, None)
        if site is None:
            return None
        bits = policy.get(site, default_bits)
    if bits <= 4 and any(k in keystr for k in _NO_PACK):
        bits = 8
    return bits


def quantize_defs(defs, *, policy: Optional[Dict[str, int]] = None,
                  default_bits: int = 8):
    """PDef tree -> tree where eligible weights become int-stored dicts.
    Layer-stacked weights (leading 'layer' axis) carry per-layer scales so
    lax.scan can slice them alongside q."""
    def walk(path, d):
        if not isinstance(d, PDef):
            return d
        keystr = jax.tree_util.keystr(path)
        bits = _bits_for(keystr, policy, default_bits)
        if bits is None or len(d.shape) < 2:
            return d
        stacked = d.axes and d.axes[0] == "layer"
        if stacked:
            scale = PDef((d.shape[0], 1), ("layer", "null"), "ones",
                         dtype=F32)
        else:
            scale = PDef((1,), ("null",), "ones", dtype=F32)
        if bits <= 4:
            shape = d.shape[:-2] + (d.shape[-2] // 2, d.shape[-1])
            return {"q4": PDef(shape, d.axes, "zeros", dtype=jnp.int8),
                    "scale": scale}
        return {"q": PDef(d.shape, d.axes, "zeros", dtype=jnp.int8),
                "scale": scale}
    return jax.tree_util.tree_map_with_path(
        walk, defs, is_leaf=lambda x: isinstance(x, PDef))


def quantize_params(params, *, policy: Optional[Dict[str, int]] = None,
                    default_bits: int = 8):
    """Materialize quantized leaves from real bf16 params."""
    # stacked (scanned) param subtrees get per-layer scales
    _STACKED = ("['blocks']", "['mamba']", "['enc']", "['dec']")

    def walk(path, w):
        keystr = jax.tree_util.keystr(path)
        bits = _bits_for(keystr, policy, default_bits)
        if bits is None or w.ndim < 2:
            return w
        wf = w.astype(F32)
        qmax = 2.0 ** (min(bits, 8) - 1) - 1.0
        if any(s in keystr for s in _STACKED) and w.ndim >= 3:
            red = tuple(range(1, w.ndim))
            amax = jnp.max(jnp.abs(wf), axis=red)            # (L,)
            scale = (amax / qmax + 1e-12)[:, None]           # (L, 1)
            div = scale.reshape((w.shape[0],) + (1,) * (w.ndim - 1))
        else:
            scale = (jnp.max(jnp.abs(wf)) / qmax + 1e-12)[None]
            div = scale[0]
        q = jnp.clip(jnp.round(wf / div), -qmax, qmax).astype(jnp.int8)
        if bits <= 4:
            lo = q[..., 0::2, :] & 0x0F
            hi = (q[..., 1::2, :] & 0x0F) << 4
            return {"q4": (lo | hi).astype(jnp.int8),
                    "scale": scale.astype(F32)}
        return {"q": q, "scale": scale.astype(F32)}
    return jax.tree_util.tree_map_with_path(
        walk, params, is_leaf=lambda x: hasattr(x, "ndim"))


def _unpack4(q: jax.Array) -> jax.Array:
    lo = (q.astype(jnp.int8) << 4) >> 4
    hi = q.astype(jnp.int8) >> 4
    stacked = jnp.stack([lo, hi], axis=-2)           # (..., K/2, 2, N)
    sh = q.shape[:-2] + (q.shape[-2] * 2, q.shape[-1])
    return stacked.reshape(sh)


def dequant_dot(x, w, name):
    """dot hook: dequantize dict-stored weights, plain einsum otherwise."""
    if not isinstance(w, dict):
        return jnp.einsum(_einsum_for(x, w), x, w)
    if "q4" in w:
        q = _unpack4(w["q4"])
    else:
        q = w["q"]
    wde = (q.astype(F32) * w["scale"]).astype(x.dtype)
    return jnp.einsum(_einsum_for(x, wde), x, wde)


def avg_weight_bits(defs_q) -> float:
    """Average stored bits per weight element (analytic memory model)."""
    import numpy as np
    elems, bits = 0.0, 0.0
    leaves = jax.tree_util.tree_flatten_with_path(
        defs_q, is_leaf=lambda x: isinstance(x, (PDef, dict))
        and (isinstance(x, PDef) or "q" in x or "q4" in x))[0]
    for path, d in leaves:
        if isinstance(d, dict):
            key = "q4" if "q4" in d else "q"
            n = float(np.prod(d[key].shape))
            logical = n * (2 if key == "q4" else 1)
            elems += logical
            bits += n * 8
        elif isinstance(d, PDef):
            n = float(np.prod(d.shape))
            elems += n
            bits += n * jnp.dtype(d.dtype).itemsize * 8
    return bits / max(elems, 1.0)
