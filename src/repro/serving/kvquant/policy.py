"""HAQ search over KV-cache bits: the paper's hardware-in-the-loop
quantization loop (core/haq.py), pointed at the paged pool instead of the
weights.

Sites are the pool's sub-layer slots (core/haq.py::enumerate_kv_sites).
Direct hardware feedback — never a FLOPs proxy — comes from the same
roofline admission queries at serve time: per-site KV read traffic from
``hardware_model.attention_cost(kv_bits=...)`` and the whole decode tick
from ``admission.step_latency``. Budget enforcement is the paper's exact
mechanism (sequentially decrease bits until the constraint holds), stepped
along KV_BITS.

Quality feedback is an *attention sensitivity proxy* rather than a trained
subject: uniform symmetric quantization at b bits carries noise variance
proportional to 2^-2(b-1), and a layer integrates that noise over its
effective context — full ``ctx`` for global attention, ``window`` for
sliding-window layers. The proxy both scores policies (reward) and hard-
gates the search space: sites whose effective context exceeds the local
window may not drop to int4 at all (``allowed_kv_bits``) — local-window
layers go first, exactly the asymmetry the roofline already exploits for
compute (window-trimmed walks).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.haq import KV_BITS, KVCacheSite, enumerate_kv_sites, resource
from repro.core.hardware_model import Hardware, V5E_EDGE
from repro.core.rl.ddpg import DDPG, DDPGConfig
from repro.serving.engine.admission import kv_bytes_per_token, step_latency

STATE_DIM = 8


def allowed_kv_bits(site: KVCacheSite) -> Tuple[int, ...]:
    """Sensitivity gate: local-window sites may drop to int4; global sites
    floor at int8 (their quantization noise integrates over the full
    context, so int4 error there dominates the drift budget)."""
    return KV_BITS if site.local else tuple(b for b in KV_BITS if b >= 8)


def kv_sensitivity(site: KVCacheSite) -> float:
    """Noise-accumulation weight of one site: log-effective-context per
    layer sharing it (softmax averaging washes out per-token noise roughly
    with the log of the number of summands, not linearly)."""
    return site.count * math.log2(site.eff_ctx + 1)


def proxy_loss(sites: Sequence[KVCacheSite],
               bits: Sequence[int]) -> float:
    """Σ sensitivity × quantizer noise variance (2^-2(b-1); 0 at bf16)."""
    total = 0.0
    for s, b in zip(sites, bits):
        if b >= 16:
            continue
        total += kv_sensitivity(s) * 2.0 ** (-2 * (b - 1))
    return total


def enforce_kv_budget(sites: Sequence[KVCacheSite], bits: List[int],
                      hw: Hardware, budget: float, mode: str) -> List[int]:
    """Paper's back-off along KV_BITS: while over budget, decrement the
    site with the largest resource share that can still go lower within
    its gate."""
    bits = list(bits)
    wa = lambda: [(b, 16) for b in bits]
    while (cur := resource(sites, wa(), hw, mode)) > budget:
        best, gain = None, 0.0
        for i, s in enumerate(sites):
            lower = [b for b in allowed_kv_bits(s) if b < bits[i]]
            if not lower:
                continue
            trial = list(bits)
            trial[i] = max(lower)
            g = cur - resource(sites, [(b, 16) for b in trial], hw, mode)
            # ">= on ties/zero gain": keep decrementing toward the gated
            # floor even when a step buys nothing in this mode (e.g. a
            # compute-bound site in latency mode), so the contract stays
            # the paper's — over budget only if even the floor is
            if g > gain or best is None:
                best, gain = (i, max(lower)), g
        if best is None:
            break                        # every site at its gated floor
        bits[best[0]] = best[1]
    return bits


def _state(sites, t: int, prev_bits: int, budget_frac: float) -> np.ndarray:
    s = sites[t]
    return np.array([
        t / max(len(sites) - 1, 1),
        np.log2(max(s.eff_ctx, 1)) / 20.0,
        float(s.local),
        s.d_in / 4096.0,
        s.count / 100.0,
        kv_sensitivity(s) / 1000.0,
        prev_bits / 16.0,
        budget_frac,
    ], np.float32)


def search_kv_policy(cfg, hw: Hardware = V5E_EDGE, *, max_model_len: int,
                     batch: int = 1, budget_frac: float = 0.55,
                     mode: str = "size", episodes: int = 16,
                     quality_coef: float = 1.0, seed: int = 0) -> Dict:
    """Search per-sub-layer KV bits under a resource budget.

    budget = ``budget_frac`` × the bf16 pool's resource in ``mode``
    ("size": resident KV HBM bytes; "latency"/"energy": the roofline
    attention terms at the quantized width). Returns a dict with the
    per-site policy, its ``sub{j}`` tuple (ready for
    ``derive_policy(kv_bits=...)``), and the serve-time feedback the
    policy was scored with (est. decode tick latency via
    admission.step_latency, bytes/token via admission.kv_bytes_per_token).

    ``episodes=0`` skips the RL loop and returns the deterministic
    sensitivity-gated back-off from all-int8 — the budget-feasible
    fallback (and a fine default for P <= 2 pools, where the search space
    is tiny)."""
    sites = enumerate_kv_sites(cfg, batch, max_model_len)
    base_bits = [16] * len(sites)
    base_res = resource(sites, [(b, 16) for b in base_bits], hw, mode)
    budget = budget_frac * base_res

    def finish(bits, extra):
        bits = enforce_kv_budget(sites, list(bits), hw, budget, mode)
        pol = {s.name: b for s, b in zip(sites, bits)}
        tup = tuple(pol[f"kv_sub{j}"] for j in range(len(sites)))
        return {
            "policy": pol,
            "bits": tup,
            "loss": proxy_loss(sites, bits),
            "resource": resource(sites, [(b, 16) for b in bits], hw, mode),
            "budget": budget,
            "base_resource": base_res,
            "kv_bytes_per_token": kv_bytes_per_token(cfg, tup),
            "kv_bytes_per_token_fp": kv_bytes_per_token(cfg),
            "est_decode_s": step_latency(cfg, batch, 1, max_model_len, hw,
                                         kv_bits=tup),
            "est_decode_s_fp": step_latency(cfg, batch, 1, max_model_len,
                                            hw),
            **extra,
        }

    if episodes <= 0:
        start = [min(8, max(allowed_kv_bits(s))) for s in sites]
        return finish(start, {"episodes": 0})

    agent = DDPG(DDPGConfig(state_dim=STATE_DIM), seed=seed)
    best: Optional[Tuple[float, List[int]]] = None
    hist = []
    for ep in range(episodes):
        bits, traj = [], []
        prev = 16
        for t in range(len(sites)):
            st = _state(sites, t, prev, budget_frac)
            a = agent.act(st, explore=True)
            arr = allowed_kv_bits(sites[t])
            b = arr[max(0, min(int(round(a * (len(arr) - 1))),
                               len(arr) - 1))]
            bits.append(b)
            traj.append((st, a))
            prev = b
        bits = enforce_kv_budget(sites, bits, hw, budget, mode)
        loss = proxy_loss(sites, bits)
        reward = -quality_coef * loss
        for t, (st, a) in enumerate(traj):
            done = t == len(traj) - 1
            s2 = _state(sites, min(t + 1, len(sites) - 1), bits[t],
                        budget_frac) if not done \
                else np.zeros(STATE_DIM, np.float32)
            agent.observe(st, a, reward if done else 0.0, s2, done)
        agent.end_episode()
        hist.append({"episode": ep, "loss": loss,
                     "bits": tuple(bits)})
        if best is None or loss < best[0]:
            best = (loss, bits)
    return finish(best[1], {"episodes": episodes, "history": hist})
