"""Greedy-drift measurement for quantized KV pools.

A quantized pool cannot promise token-identical greedy outputs — it
promises *bounded logit drift*. The right measurement is teacher-forced:
replay one fixed token stream through an fp pool and a quantized pool and
compare the per-step logits. Under teacher forcing both runs see identical
contexts, so the logit gap is exactly the KV-quantization error — no
argmax-flip cascade pollutes it.

The token-level statement this licenses (asserted in tests/test_kvquant.py
and reported by benchmarks/bench_engine_throughput.py): a greedy quantized
run is token-identical to the fp run until the first step whose fp top-2
logit margin is within 2x the measured drift — any flip beyond that margin
would need a logit error larger than the bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine.pool import quiet_donation
from repro.serving.kvquant.quantize import quantize_pool


def _identity_pool(cache, max_len: int, page: int):
    """B=1 identity-mapped page pool from a full-layout prefill cache:
    logical block i at physical page 1 + i (page 0 stays scratch), the same
    layout launch.serve's sequential baseline decodes through."""
    ppseq = -(-max_len // page)
    span = ppseq * page
    pt = np.arange(1, ppseq + 1, dtype=np.int32)[None]

    def to_pages(c):                     # (G, 1, S, K, hd) full layout
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, span - c.shape[2])
        c = jnp.pad(c, pad)[:, 0]
        c = c.reshape(c.shape[0], ppseq, page, *c.shape[2:])
        pool = jnp.zeros((c.shape[0], ppseq + 1) + c.shape[2:], c.dtype)
        return pool.at[:, 1:].set(c)

    return jax.tree.map(to_pages, cache), jnp.asarray(pt)


def teacher_forced_logits(model, params, tokens, prompt_len: int, *,
                          page_size: int = 16, kv_bits=None,
                          kernel: str = "auto") -> np.ndarray:
    """Replay ``tokens`` (prompt + continuation) through a paged pool,
    feeding the given continuation instead of sampling, and return the fp32
    logits the model emits for every continuation position —
    ``out[i]`` predicts ``tokens[prompt_len + i]``.

    ``kv_bits=None`` replays through the fp pool; otherwise the prefill
    cache is converted with the writers' per-token quantization mapping and
    decode quantizes on write, so the replay exercises exactly the serving
    path (fused-dequant walk included)."""
    tokens = np.asarray(tokens, np.int32)
    T = len(tokens)
    assert 0 < prompt_len < T, (prompt_len, T)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(tokens[None, :prompt_len])},
        cache_layout="full")
    pool, pt = _identity_pool(cache, T, page_size)
    if kv_bits is not None:
        pool = quantize_pool(pool, model.cfg, kv_bits)
    decode = jax.jit(
        lambda p, pool, pt, t, pos: model.decode_step_paged(
            p, pool, pt, t, pos, kernel=kernel),
        donate_argnums=(1,))
    out = [np.asarray(logits[0, -1], np.float32)]
    for t in range(prompt_len, T - 1):
        with quiet_donation():
            logits, pool = decode(params, pool, pt,
                                  jnp.asarray(tokens[None, t:t + 1]),
                                  jnp.asarray([t], jnp.int32))
        out.append(np.asarray(logits[0, 0], np.float32))
    return np.stack(out)


def greedy_drift(model, params, tokens, prompt_len: int, *,
                 kv_bits, page_size: int = 16, kernel: str = "auto",
                 fp_logits: np.ndarray = None) -> dict:
    """Max-abs teacher-forced logit drift of a KV bit policy vs the fp pool
    over one token stream, plus the top-2 fp margin at every step (what a
    flip must beat). Keys: ``max_abs`` drift, ``margins`` (n,) fp top-2
    gaps, ``flip_steps`` indices where the quantized argmax differs,
    ``fp_logits`` — pass the latter back in to compare several bit
    policies against one fp replay instead of re-running it."""
    fp = fp_logits if fp_logits is not None else \
        teacher_forced_logits(model, params, tokens, prompt_len,
                              page_size=page_size, kernel=kernel)
    qq = teacher_forced_logits(model, params, tokens, prompt_len,
                               page_size=page_size, kv_bits=kv_bits,
                               kernel=kernel)
    drift = float(np.max(np.abs(fp - qq)))
    top2 = np.sort(fp, axis=-1)[:, -2:]
    margins = top2[:, 1] - top2[:, 0]
    flips = np.nonzero(np.argmax(fp, -1) != np.argmax(qq, -1))[0]
    return {"max_abs": drift, "margins": margins,
            "flip_steps": flips.tolist(), "fp_logits": fp}
