"""Pool-level KV quantization utilities.

The element-wise storage mapping (symmetric per-token per-head scales,
int4 packed along head_dim) lives in kernels/ref.py next to the attention
oracles that consume it — the Pallas kernel, the pure-JAX walk, and the
pool writers must all agree on it bit-for-bit. This module re-exports those
primitives as the subsystem's public API and adds the pytree-level
converter used for offline pool conversion and drift measurement.
"""
from __future__ import annotations

from repro.kernels.ref import (dequantize_kv, kv_bits_of, pack_int4_hd,
                               quantize_kv, unpack_int4_hd)
from repro.models.transformer import normalize_kv_bits

__all__ = ["quantize_kv", "dequantize_kv", "kv_bits_of", "pack_int4_hd",
           "unpack_int4_hd", "quantize_pool", "normalize_kv_bits"]


def quantize_pool(pool, cfg, kv_bits):
    """Convert an fp page-pool pytree (Model.init_pool layout) to the
    quantized layout under ``kv_bits`` (anything normalize_kv_bits takes).

    Every resident slot is quantized with the same per-token per-head
    mapping the writers use, so a converted pool is indistinguishable from
    one filled by quantize-on-write (up to slots the mask never reads —
    scratch/garbage slots get quantized too, harmlessly). Slots whose
    policy entry is 16 pass through as bf16."""
    bits = normalize_kv_bits(cfg, kv_bits)
    if bits is None:
        return pool
    out = {}
    for sub, kv in pool.items():
        b = bits[int(sub[3:])]
        if b == 16:
            out[sub] = kv
            continue
        out[sub] = {}
        for name in ("k", "v"):
            q, scale = quantize_kv(kv[name], b)
            out[sub][name] = {"q": q, "scale": scale}
    return out
