"""HAQ-searched mixed-precision KV-cache quantization for the serving
engine's paged pool.

PR 1 put HAQ bits on the *weights* when the memory roofline demanded it; at
long contexts the decode roofline is dominated by KV-cache bytes, not
weight bytes. This subsystem turns the same searched-bit machinery loose on
the pool itself: pages are stored int8 or int4 per sub-layer slot, sized
into admission (2-4x more pages in the same HBM), and dequantized *inside*
the paged-attention block walk — never as a materialized fp KV view.

Quantized page layout
---------------------
The fp pool stores, per sub-layer slot ``sub{j}`` (see serving/engine)::

    pool["sub{j}"]["k"|"v"] : (n_groups, num_pages, page_size, K, hd) bf16

A slot quantized to ``bits`` ∈ {8, 4} stores instead::

    pool["sub{j}"]["k"|"v"] = {
        "q":     (n_groups, num_pages, page_size, K, hd_store) int8,
        "scale": (n_groups, num_pages, page_size, K)            fp32,
    }

with ``hd_store = hd`` for int8 and ``hd // 2`` for int4 — int4 packs two
codes per byte along head_dim (element ``2i`` in the low nibble, ``2i+1``
in the high; kernels/ref.py::pack_int4_hd). The stored bitwidth is encoded
by the shape itself (``kv_bits_of``), so it stays static under jit and no
side-channel bits tag rides the pytree.

Scale placement
---------------
Scales are symmetric per page *slot* (token) and per kv head: each physical
page carries its own ``(page_size, K)`` fp32 scale tile next to its codes.
Per-token granularity is what makes quantize-on-write exact bookkeeping:
prefill scatters whole quantized pages, decode writes one ``(K, hd)`` token
into ``page_table[b, pos // page]`` slot ``pos % page`` — and neither ever
re-scales a resident token (a per-page scale would have to grow as new
tokens land, forcing a lossy requantize of the whole page on every write).
The coarser per-page granularity is kept in ``quantize_kv`` for the
error-bound study in tests/test_kvquant.py. Scale overhead is
``8 * K`` bytes per token per layer (k and v), priced into
``admission.kv_bytes_per_token`` so page sizing stays honest.

At attention time the scale tiles ride the same scalar-prefetched
page-table walk as their pages (kernels/paged_attention.py::
paged_attention_quant_fwd on TPU, kernels/ref.py::paged_attention_quant_ref
as the pure-JAX twin): dequant happens inside the online-softmax block
loop, one (page, hd) fp tile in VMEM at a time.

Bit policy
----------
``policy.search_kv_policy`` runs the paper's HAQ loop over KV sites
(core/haq.py::enumerate_kv_sites — one per sub-layer slot, matching the
pool pytree): DDPG proposes per-site bits, latency/HBM feedback comes from
the hardware roofline (hardware_model.attention_cost with ``kv_bits``,
admission.step_latency for the whole tick), the paper's sequential back-off
enforces the budget, and an attention-sensitivity proxy gates which sites
may drop to int4 — sliding-window (local) layers first, since their bounded
effective context bounds the quantization-noise accumulation. The searched
policy is a per-sub-layer tuple that threads through
``AdmissionPolicy.kv_bits`` -> ``Engine`` -> ``Model.init_pool``.

The fp pool remains the exactness baseline; int8 greedy drift against it is
bounded and asserted in tests/test_kvquant.py, and
benchmarks/bench_engine_throughput.py headlines fp vs int8 vs HAQ-mixed
decode throughput at equal HBM budget (BENCH_engine.json).
"""
from repro.serving.kvquant.drift import greedy_drift, teacher_forced_logits
from repro.serving.kvquant.quantize import (dequantize_kv, kv_bits_of,
                                            normalize_kv_bits, pack_int4_hd,
                                            quantize_kv, quantize_pool,
                                            unpack_int4_hd)
from repro.serving.kvquant.policy import (kv_sensitivity, search_kv_policy,
                                          allowed_kv_bits)

__all__ = ["quantize_kv", "dequantize_kv", "kv_bits_of", "pack_int4_hd",
           "unpack_int4_hd", "quantize_pool", "normalize_kv_bits",
           "search_kv_policy", "kv_sensitivity", "allowed_kv_bits",
           "greedy_drift", "teacher_forced_logits"]
