"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, MoE interleaved with
dense layers 1:1 (matches the ~400B total / ~17B active budget; an all-MoE
stack would be ~770B). Early fusion noted; text backbone only per spec.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    activation="swiglu",
    moe=MoEConfig(num_experts=128, experts_per_token=1, d_ff_expert=8192,
                  every=2, offset=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
