"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""
from __future__ import annotations

from repro.configs.base import (ModelConfig, MoEConfig, OptimConfig,
                                ShapeConfig, SSMConfig, TrainConfig, SHAPES)

__all__ = ["ModelConfig", "MoEConfig", "OptimConfig", "ShapeConfig",
           "SSMConfig", "TrainConfig", "SHAPES", "ARCHS", "ALIASES",
           "get_config", "get_shape", "assigned_cells", "tiny_config"]

from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.mistral_large_123b import CONFIG as _mistral
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.granite_moe_3b import CONFIG as _granite_moe
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.supernet_lm import BACKBONE as _supernet

ARCHS = {
    c.name: c
    for c in [_granite, _mistral, _nemotron, _gemma2, _whisper, _llava,
              _llama4, _granite_moe, _zamba2, _mamba2, _supernet]
}

# Short aliases accepted by --arch.
ALIASES = {
    "granite-3-8b": "granite-3-8b",
    "mistral-large-123b": "mistral-large-123b",
    "nemotron-4-15b": "nemotron-4-15b",
    "gemma2-2b": "gemma2-2b",
    "whisper-large-v3": "whisper-large-v3",
    "llava-next-mistral-7b": "llava-next-mistral-7b",
    "llama4-maverick-400b-a17b": "llama4-maverick-400b-a17b",
    "llama4-maverick-400b": "llama4-maverick-400b-a17b",
    "granite-moe-3b-a800m": "granite-moe-3b-a800m",
    "granite-moe-3b": "granite-moe-3b-a800m",
    "zamba2-1.2b": "zamba2-1.2b",
    "mamba2-370m": "mamba2-370m",
    "supernet-lm": "supernet-lm",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def assigned_cells():
    """The graded (arch x shape) cells: every supported shape per arch."""
    cells = []
    for name, cfg in ARCHS.items():
        if name == "supernet-lm":
            continue
        for shape in cfg.supported_shapes:
            cells.append((name, shape))
    return cells


def tiny_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch)
    kw = dict(
        num_layers=min(cfg.num_layers, 4 if not cfg.shared_attn_every else 6),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.num_heads:
        kw["num_heads"] = 4
        kw["num_kv_heads"] = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    if cfg.moe:
        kw["moe"] = cfg.moe.__class__(
            num_experts=4,
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            d_ff_expert=64,
            every=cfg.moe.every,
            offset=cfg.moe.offset,
            # effectively drop-free so prefill/decode equivalence is exact
            capacity_factor=4.0,
        )
    if cfg.ssm:
        kw["ssm"] = cfg.ssm.__class__(
            d_state=16, expand=2, head_dim=32, n_groups=1, conv_width=4,
            chunk=16)
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 3
    if cfg.window_size:
        kw["window_size"] = 32
    return cfg.replace(name=cfg.name + "-tiny", **kw)
