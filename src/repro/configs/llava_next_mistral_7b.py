"""llava-next-mistral-7b [vlm] — mistral-7b backbone; anyres vision tiling is
a STUB: input_specs() provides precomputed patch embeddings for the first
patch_frac of the sequence. [hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    activation="swiglu",
    frontend="vision_stub",
    patch_frac=0.25,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
