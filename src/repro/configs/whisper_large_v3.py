"""whisper-large-v3 [audio] — enc-dec; conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, S, d_model). [arXiv:2212.04356]

num_layers=32 applies to both the encoder and the decoder stacks.
Decoder length = seq_len // dec_ratio. MHA (kv == q heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    rope_theta=0.0,  # sinusoidal positions, no RoPE
    is_encdec=True,
    dec_ratio=8,
    frontend="audio_stub",
    source="arXiv:2212.04356",
)
