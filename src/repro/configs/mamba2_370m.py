"""mamba2-370m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]

long_500k supported (decode state is O(1) in sequence length).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    activation="swiglu",
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, chunk=256),
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2405.21060",
)
