"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
GeGLU, sandwich norms, head_dim 256. [arXiv:2408.00118; hf]

long_500k is supported: the interleaved local layers bound their KV window at
4096; global layers keep the full cache (hybrid-local, see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    activation="geglu",
    attn_pattern=("local", "global"),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2408.00118",
)
