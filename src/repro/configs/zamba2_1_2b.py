"""zamba2-1.2b [hybrid] — Mamba2 core stack + one SHARED attention+FFN block
applied every 6 mamba layers, input fused with the original embedding
(concat -> proj), per the Zamba2 design. [arXiv:2411.15242; hf]

Sub-quadratic (SSM core, shared-attn KV only) -> long_500k supported.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    activation="gelu",
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1, chunk=256),
    shared_attn_every=6,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2411.15242",
)
