"""Config dataclasses shared by every architecture in the zoo.

A ``ModelConfig`` fully determines parameter shapes and the forward graph;
``ShapeConfig`` is one of the four assigned input-shape cells. Everything is
frozen/hashable so configs can be jit static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    # Which layers are MoE: every `every`-th layer starting at `offset`.
    every: int = 1
    offset: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    num_heads: int = 0          # mamba2 heads; 0 -> d_inner // head_dim
    head_dim: int = 64
    n_groups: int = 1           # B/C groups (GQA-analogue for SSM)
    conv_width: int = 4
    chunk: int = 256            # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu | squared_relu | gelu
    # Attention pattern cycled over layers, e.g. ("local", "global") for gemma2.
    attn_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    sandwich_norm: bool = False  # gemma2 post-sublayer norms
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma-style sqrt(d_model) embed scaling
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention block applied every k core layers
    shared_attn_every: int = 0
    # enc-dec (whisper): num_layers applies to BOTH encoder and decoder
    is_encdec: bool = False
    dec_ratio: int = 8          # decoder_len = seq_len // dec_ratio
    # modality frontends are stubs: input_specs() provides embeddings directly
    frontend: str = "none"      # none | audio_stub | vision_stub
    patch_frac: float = 0.25    # vlm: fraction of sequence that is patches
    dtype: str = "bfloat16"
    # Which shape cells this arch supports ("train_4k", ... ). long_500k is
    # only listed for sub-quadratic archs (see DESIGN.md §4).
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    # HAQ/AMC hooks
    quant_policy: Optional[Tuple[Tuple[str, int], ...]] = None  # (layer_kind, bits)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/lm_head/logits stay shardable
        on any mesh axis (Megatron-style vocab parallelism). Ids >= vocab_size
        are masked out of the softmax."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        if not self.ssm:
            return 0
        return self.ssm.num_heads or (self.d_inner // self.ssm.head_dim)

    def is_moe_layer(self, i: int) -> bool:
        return bool(self.moe) and (i - self.moe.offset) % self.moe.every == 0 \
            and i >= self.moe.offset

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per_attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
        gated = self.activation in ("swiglu", "geglu")
        per_ffn = d * self.d_ff * (3 if gated else 2)
        blocks = 0
        n_stacks = 2 if self.is_encdec else 1
        for i in range(self.num_layers):
            if self.ssm and not self._is_attn_layer(i):
                di = self.d_inner
                g, n = self.ssm.n_groups, self.ssm.d_state
                nh = self.ssm_heads
                in_proj = d * (2 * di + 2 * g * n + nh)
                blocks += in_proj + di * d + di * self.ssm.conv_width + 3 * nh
            else:
                blocks += per_attn
                if self.is_moe_layer(i):
                    m = self.moe
                    e_ff = m.d_ff_expert
                    blocks += m.num_experts * d * e_ff * (3 if gated else 2)
                    blocks += d * m.num_experts  # router
                elif self.d_ff:
                    blocks += per_ffn
        blocks *= n_stacks
        if self.is_encdec:  # cross attention in decoder
            blocks += self.num_layers * per_attn
        if self.shared_attn_every:
            blocks += per_attn + per_ffn + 2 * d * d  # shared block + fuse proj
        return emb + head + blocks

    def _is_attn_layer(self, i: int) -> bool:
        """For hybrid/ssm families: which core layers are attention."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return False  # zamba2 core stack is all-mamba; attn is the shared block
        return True


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # int8 block-quantized Adam moments (beyond-paper, HAQ-themed; needed to
    # fit 400B-param optimizer state on a 16GiB/chip pod).
    quantized_moments: bool = False
    moment_block: int = 128


@dataclass(frozen=True)
class TrainConfig:
    optim: OptimConfig = field(default_factory=OptimConfig)
    remat: bool = True
    # gradient accumulation: global batch is split into `microbatches` chunks
    # scanned sequentially — bounds live activation memory for the 100B+
    # archs (grads accumulate in sharded fp32)
    microbatches: int = 1
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
