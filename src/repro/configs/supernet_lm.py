"""The paper's own search space (§2), adapted to LM blocks (DESIGN.md §2).

ProxylessNAS CNN space: per block, MBConv {k3,k5,k7} x {e3,e6} + ZeroOp = 7
choices. LM adaptation keeps a 7-way mixed op per block:

  attention arm: {full_gqa, local_1k, local_4k}     (receptive-field analogue
                                                     of kernel size 3/5/7)
  ffn arm:       {swiglu_e2, swiglu_e4}             (expansion-ratio analogue
                                                     of e3/e6, applied to the
                                                     whole block's FFN)
  ssm arm:       {mamba2}                           (TPU-native linear-time op
                                                     the searcher may discover)
  zero arm:      {zero}                             (block skip)

Design-space size = 7^N, N = 21 blocks — identical to the paper.
"""
from repro.configs.base import ModelConfig, SSMConfig

# Candidate op ids, in LUT/arch-param order.
CANDIDATE_OPS = (
    "attn_full_e2",
    "attn_full_e4",
    "attn_local1k_e2",
    "attn_local1k_e4",
    "attn_local4k_e4",
    "mamba2_e2",
    "zero",
)

# Backbone dims for the supernet (≈100M-scale so the end-to-end example can
# actually train a specialized child on CPU).
BACKBONE = ModelConfig(
    name="supernet-lm",
    family="dense",
    num_layers=21,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    activation="swiglu",
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1, chunk=128),
    source="paper §2 (ProxylessNAS space, LM-adapted)",
)
