"""The production trainer loop: checkpoint/restart, straggler monitoring,
logging — the thing launch/train.py drives.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.data import pipeline as dp
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.training import steps as steps_lib


def train(model, shape, tcfg, *, mesh=None, ac=None, dot=None,
          num_steps: int = 100, dcfg: Optional[dp.DataConfig] = None,
          log: Callable[[dict], None] = lambda r: print(r, flush=True),
          in_shardings=None) -> Dict:
    """Returns {state, history}. Resumes from tcfg.checkpoint_dir if a
    checkpoint exists (exact resume: deterministic data keyed by step)."""
    step_fn = steps_lib.make_train_step(model, tcfg, ac=ac, dot=dot)
    if in_shardings is not None:
        step_fn = jax.jit(step_fn, in_shardings=in_shardings,
                          out_shardings=in_shardings[0:1] + (None,),
                          donate_argnums=(0,))
    else:
        # no donation on the single-host path: XLA:CPU deduplicates identical
        # zero-init buffers (m/v/norm-scales), and donating an aliased buffer
        # twice is an error; memory pressure is not a concern at CPU scale
        step_fn = jax.jit(step_fn)

    start = latest_step(tcfg.checkpoint_dir)
    state = steps_lib.init_train_state(model, tcfg,
                                       jax.random.PRNGKey(tcfg.seed))
    if start is not None:
        state, start = restore(tcfg.checkpoint_dir, state)
        log({"event": "restored", "step": start})
        start += 1
    else:
        start = 0

    ckpt = AsyncCheckpointer(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
    monitor = StragglerMonitor()
    history = []
    for step in range(start, num_steps):
        batch = dp.batch_for_model(model, shape, dcfg, step)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])  # blocks; = device sync point
        dt = time.time() - t0
        monitor.record(step, dt)
        if step % tcfg.log_every == 0 or step == num_steps - 1:
            rec = {"step": step, "loss": round(loss, 4),
                   "grad_norm": round(float(metrics["grad_norm"]), 3),
                   "dt_s": round(dt, 3)}
            history.append(rec)
            log(rec)
        if tcfg.checkpoint_every and step and \
                step % tcfg.checkpoint_every == 0:
            ckpt.save(step, state)
    ckpt.wait()
    if tcfg.checkpoint_every:
        from repro.checkpoint.ckpt import save as sync_save
        sync_save(tcfg.checkpoint_dir, num_steps - 1, state,
                  keep=tcfg.keep_checkpoints)
    return {"state": state, "history": history,
            "straggler_events": monitor.events}
