"""Step builders: train_step / prefill_step / serve_step.

These are the exact functions the dry-run lowers for the production meshes
and the trainer/server run on real hardware. `ac` is the activation-sharding
hook (distributed.sharding.make_ac); `dot` the HAQ quantized-matmul hook.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.optim.adamw import adamw_init, adamw_update

F32 = jnp.float32


def make_train_step(model, tcfg, *, ac=None, dot=None) -> Callable:
    ocfg = tcfg.optim
    M = tcfg.microbatches

    def grad_fn(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=tcfg.remat, ac=ac, dot=dot)
        return jax.value_and_grad(loss_fn)(params)

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if M > 1:
            micro = jax.tree.map(
                lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]),
                batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(lambda a, b: a + b.astype(F32),
                                     g_acc, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), F32), zero), micro)
            loss = loss / M
            grads = jax.tree.map(lambda g: g / M, grads)
        else:
            loss, grads = grad_fn(params, batch)
        new_params, new_opt, metrics = adamw_update(grads, state["opt"], ocfg)
        new_state = {"params": new_params, "opt": new_opt}
        return new_state, {"loss": loss, **metrics}

    return train_step


def init_train_state(model, tcfg, key):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params, tcfg.optim)}


def abstract_train_state(model, tcfg):
    """ShapeDtypeStruct mirror of init_train_state (dry-run, no allocation)."""
    params = model.abstract_params()

    def moment(p):
        if tcfg.optim.quantized_moments:
            from repro.optim.adamw import moment_block_for
            b = moment_block_for(p.shape, tcfg.optim.moment_block)
            nb = (p.shape[-1] // b) if p.shape else 1
            return {
                "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "scale": jax.ShapeDtypeStruct(p.shape[:-1] + (nb,), F32),
            }
        return jax.ShapeDtypeStruct(p.shape, F32)

    return {
        "params": params,
        "opt": {
            "master": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, F32), params),
            "m": jax.tree.map(moment, params),
            "v": jax.tree.map(moment, params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }


def train_state_logical_specs(model, tcfg):
    from repro.optim.adamw import opt_state_logical_specs
    pspecs = model.logical_specs()
    return {
        "params": pspecs,
        "opt": opt_state_logical_specs(pspecs, tcfg.optim),
    }


def make_prefill_step(model, *, ac=None, dot=None) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, ac=ac, dot=dot)

    return prefill_step


def make_serve_step(model, *, ac=None, dot=None) -> Callable:
    def serve_step(params, cache, token, pos):
        logits, new_cache = model.decode_step(params, cache, token, pos,
                                              ac=ac, dot=dot)
        return logits, new_cache

    return serve_step
