"""Divisibility-aware logical→mesh sharding rules.

Every tensor in the system carries logical axis names (see models/params.py).
``specs_for`` maps a pytree of (shapes × logical axes) onto a mesh by walking
each tensor's dims left-to-right and assigning the first *legal* candidate
mesh-axis tuple per logical axis — legal means (a) the dim is divisible by the
mesh-axes product and (b) no mesh axis is used twice within one tensor.

This is what lets one fixed production mesh (16×16 / 2×16×16) serve all ten
architectures: gemma2's 8 Q heads or granite's 49155 vocab simply fall through
to the next candidate instead of failing to lower (see DESIGN.md §3).

The SPMD serving engine (serving/engine/sharded.py) builds its shard_map
specs from the same rules — ``kv_heads`` carries the paged KV pool there,
and the invariants (no double-used axis, divisibility, replicate as the
last resort) have direct property coverage in tests/test_distribution.py.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# fsdp == param/batch sharding axes; model == tensor-parallel axis.
FSDP = ("pod", "data")

# Candidate mesh-axis tuples per logical axis, in priority order. The empty
# tuple (replicate) is always the implicit last resort.
CANDIDATES: Dict[str, Sequence[Tuple[str, ...]]] = {
    # params
    "vocab": [("model",)],
    "embed": [FSDP, ("data",)],
    "embed2": [],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "d_ff": [("model",)],
    "experts": [("model",)],
    "expert_ff": [("model",)],
    "ssm_inner": [("model",)],
    "ssm_heads": [("model",)],
    "ssm_state": [],
    "conv": [],
    "layer": [],
    "null": [],
    "moment_blocks": [FSDP, ("data",)],
    # activations / caches
    "batch": [FSDP, ("data",)],
    "seq": [("data",)],
    "cache_seq": [("model",), ("data",)],
    "embed_act": [],
}


def _axes_in_mesh(
    mesh: Mesh, axes: Tuple[str, ...]
) -> Optional[Tuple[str, ...]]:
    present = tuple(a for a in axes if a in mesh.shape)
    return present or None


def choose_spec(
    shape: Tuple[int, ...], logical: Tuple[Optional[str], ...], mesh: Mesh
) -> P:
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        placed = None
        for cand in CANDIDATES.get(name or "", []):
            axes = _axes_in_mesh(mesh, cand)
            if not axes:
                continue
            if any(a in used for a in axes):
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size != 0:
                continue
            placed = axes if len(axes) > 1 else axes[0]
            used.update(axes)
            break
        out.append(placed)
    while out and out[-1] is None:  # trailing Nones are implicit
        out.pop()
    return P(*out)


def specs_for(abstract: Any, logical: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings matching `abstract` (ShapeDtypeStructs)."""
    flat_a, tdef = jax.tree.flatten(abstract)
    flat_l = tdef.flatten_up_to(logical)
    out = []
    for a, l in zip(flat_a, flat_l):
        if l is None:
            l = (None,) * a.ndim
        out.append(NamedSharding(mesh, choose_spec(a.shape, l, mesh)))
    return jax.tree.unflatten(tdef, out)


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def make_ac(mesh: Mesh, mode: str = "dp"):
    """Activation-sharding hook threaded through the models.

    mode="dp":     residual stream (batch=fsdp, seq=None, embed=None) —
                   Megatron-style TP: activations replicated over the model
                   axis, XLA inserts fp32 partial-sum all-reduces after every
                   TP matmul (~3x B*S*d f32 per layer: the dominant
                   collective in the baseline roofline).
    mode="seq_tp": sequence-parallel TP (Korthikanti et al. 2022): between
                   blocks the residual is ALSO sharded seq-over-model, so
                   XLA lowers the boundary to bf16 all-gather +
                   reduce-scatter instead of fp32 all-reduce, and the
                   norms/residual math runs 1/TP as large."""
    fsdp = _axes_in_mesh(mesh, FSDP)

    def _batch_axes(b: int):
        if fsdp:
            size = int(np.prod([mesh.shape[a] for a in fsdp]))
            if b % size == 0:
                return fsdp if len(fsdp) > 1 else fsdp[0]
        if "data" in mesh.shape and b % mesh.shape["data"] == 0:
            return "data"
        return None

    model_ok = "model" in mesh.shape

    def ac(x, kind):
        # NOTE "moe_buf" is intentionally a no-op: constraining the dispatch
        # buffer (E, C@data, D) was MEASURED to make collectives 7x WORSE
        # (48.8s -> 342.8s, granite-moe train_4k) — the capacity-sharded
        # buffer fights the D@fsdp expert einsums. See EXPERIMENTS.md §Perf
        # M2 (refuted) and the shard_map local-dispatch plan.
        ba = _batch_axes(x.shape[0])
        if ba is None:
            return x
        if kind == "resid" and x.ndim == 3:
            if (
                mode == "seq_tp"
                and model_ok
                and x.shape[1] % mesh.shape["model"] == 0
                and x.shape[1] > 1
            ):
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(ba, "model", None))
                )
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba))
            )
        # flash-decoding-style sequence-parallel decode attention: q tiny ->
        # replicated over model; kv/scores sharded over the cache-seq dim.
        # Without these hints XLA reshards the CACHE to match heads-sharded
        # q: an 80 GiB/token all-gather (EXPERIMENTS.md §Perf D2).
        if kind == "decode_q" and x.ndim == 4:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba))
            )
        if (
            kind == "decode_kv"
            and x.ndim == 4
            and model_ok
            and x.shape[1] % mesh.shape["model"] == 0
        ):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, "model"))
            )
        if (
            kind == "decode_scores"
            and x.ndim == 4
            and model_ok
            and x.shape[-1] % mesh.shape["model"] == 0
        ):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, None, None, "model"))
            )
        return x

    return ac


def describe(shardings: Any, abstract: Any, limit: int = 0) -> str:
    """Human-readable sharding table (debug / EXPERIMENTS.md)."""
    lines = []
    flat_s = jax.tree.leaves(shardings)
    flat_a, _ = jax.tree.flatten(abstract)
    paths = [
        jax.tree_util.keystr(p)
        for p, _ in jax.tree_util.tree_flatten_with_path(abstract)[0]
    ]
    for path, s, a in zip(paths, flat_s, flat_a):
        lines.append(f"{path:70s} {str(a.shape):28s} {s.spec}")
        if limit and len(lines) >= limit:
            lines.append("...")
            break
    return "\n".join(lines)
