"""Fault tolerance & elasticity for the multi-pod trainer.

What runs where:
  * checkpoint/restart — the trainer loop (training/loop.py) saves async
    every K steps and discovers the restart point via ckpt.latest_step; the
    data pipeline is stateless-deterministic so resume is exact.
  * straggler mitigation — per-step deadline monitor: a host whose step time
    exceeds `multiplier` x the trailing median is flagged; after
    `strikes` consecutive flags the runner is asked to evict/replace the
    host (on CPU we log and simulate). Synchronous SPMD training cannot
    proceed without the host, so mitigation = evict + elastic re-mesh.
  * elastic re-mesh — rebuild the mesh with fewer data-parallel rows and
    reshard the checkpointed state onto it: shrink_mesh() computes the
    largest valid (data', model) grid from the survivors, and the sharding
    rules (divisibility-aware) re-derive every spec for the new mesh.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, List, Optional

import jax
import numpy as np

from repro.distributed import sharding as shlib


@dataclasses.dataclass
class StragglerConfig:
    window: int = 16  # trailing steps for the median
    multiplier: float = 2.0  # deadline = multiplier x median
    strikes: int = 3  # consecutive violations before eviction


class StragglerMonitor:
    """Detects slow steps; in a real deployment the callback triggers the
    cluster runner's evict-and-replace. Synchronous data-parallel training
    makes per-host timing visible as global step-time inflation."""

    def __init__(
        self,
        cfg: StragglerConfig = StragglerConfig(),
        on_straggler: Optional[Callable[[dict], None]] = None,
    ):
        self.cfg = cfg
        self.times: Deque[float] = deque(maxlen=cfg.window)
        self.strikes = 0
        self.events: List[dict] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step breached the deadline."""
        breached = False
        if len(self.times) >= 4:
            med = float(np.median(self.times))
            if dt > self.cfg.multiplier * med:
                self.strikes += 1
                breached = True
                ev = {
                    "step": step,
                    "dt": dt,
                    "median": med,
                    "strikes": self.strikes,
                }
                self.events.append(ev)
                if self.strikes >= self.cfg.strikes and self.on_straggler:
                    self.on_straggler(ev)
                    self.strikes = 0
            else:
                self.strikes = 0
        self.times.append(dt)
        return breached


def shrink_mesh(n_devices: int, model_axis: int):
    """Largest (data, model) mesh from surviving devices (elastic re-mesh).
    Keeps the model axis intact (TP groups must stay whole); drops remainder
    devices beyond the largest multiple."""
    data = n_devices // model_axis
    assert data >= 1, (n_devices, model_axis)
    usable = data * model_axis
    devs = jax.devices()[:usable]
    import numpy as _np
    from jax.sharding import Mesh

    return Mesh(
        _np.asarray(devs).reshape(data, model_axis), ("data", "model")
    )


def reshard_state(state, model, tcfg, new_mesh):
    """Re-derive every sharding for the new mesh and device_put the state.
    Used after elastic shrink/grow; the divisibility-aware rules recompute
    legal specs (a batch no longer divisible falls back gracefully)."""
    from repro.training.steps import train_state_logical_specs

    specs = shlib.specs_for(
        jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        ),
        train_state_logical_specs(model, tcfg),
        new_mesh,
    )
    return jax.device_put(state, specs)


class Heartbeat:
    """Host-liveness file heartbeat (the cluster-runner contract): each host
    touches its file every step; a coordinator (or the runner) declares a
    host dead after `timeout_s` of silence. CPU-side stand-in for the TPU
    runtime's health service."""

    def __init__(self, path: str, timeout_s: float = 60.0):
        self.path = path
        self.timeout_s = timeout_s

    def beat(self):
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def alive(self) -> bool:
        try:
            with open(self.path) as f:
                return time.time() - float(f.read()) < self.timeout_s
        except (FileNotFoundError, ValueError):
            return False
