"""AdamW in pure JAX, FSDP-friendly.

Layout: model params live in bf16 (collectives move bf16); the optimizer
holds an fp32 master copy plus first/second moments. Moments can optionally
be stored int8 with per-block fp32 scales (OptimConfig.quantized_moments) —
a beyond-paper trick in the paper's own spirit (quantize what dominates
memory): it cuts optimizer HBM from 12 to 6 bytes/param, which is what lets
llama4-maverick-400b train on a single 256-chip v5e pod (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

F32 = jnp.float32


# ------------------------------------------------------- moment quantizer ----
def moment_block_for(shape, block: int) -> int:
    """Quantization block along the LAST dim only — the int8 buffer keeps the
    param's exact shape (and therefore its sharding). Flattening to
    (n//128, 128) was observed to force involuntary full rematerialization in
    the SPMD partitioner (layout mismatch vs the fp32 grads)."""
    last = shape[-1] if shape else 1
    return block if last % block == 0 else last


def quantize_moment(x: jax.Array, block: int) -> Dict[str, jax.Array]:
    xf = x.astype(F32)
    b = moment_block_for(x.shape, block)
    g = xf.reshape(x.shape[:-1] + (x.shape[-1] // b, b))
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale[..., 0]}


def dequantize_moment(qs: Dict[str, jax.Array], shape) -> jax.Array:
    q = qs["q"].astype(F32)
    nb = qs["scale"].shape[-1]
    b = shape[-1] // nb
    g = q.reshape(shape[:-1] + (nb, b)) * qs["scale"][..., None]
    return g.reshape(shape)


# ----------------------------------------------------------------- state ----
def _moment_like(p: jax.Array, ocfg):
    if ocfg.quantized_moments:
        return quantize_moment(jnp.zeros(p.shape, F32), ocfg.moment_block)
    return jnp.zeros(p.shape, F32)


def adamw_init(params, ocfg) -> Dict[str, Any]:
    return {
        "master": jax.tree.map(lambda p: p.astype(F32), params),
        "m": jax.tree.map(lambda p: _moment_like(p, ocfg), params),
        "v": jax.tree.map(lambda p: _moment_like(p, ocfg), params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_logical_specs(param_specs, ocfg):
    """Logical specs for the optimizer state, mirroring the param specs.
    Quantized moments keep the param's exact shape (q) so they inherit its
    axes; the per-block scale drops the last (blocked) axis to replicated."""
    def moment_spec(spec):
        if ocfg.quantized_moments:
            return {"q": spec, "scale": spec[:-1] + (None,) if spec else ()}
        return spec
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return {
        "master": param_specs,
        "m": jax.tree.map(moment_spec, param_specs, is_leaf=is_axes),
        "v": jax.tree.map(moment_spec, param_specs, is_leaf=is_axes),
        "count": (),
    }


# ---------------------------------------------------------------- update ----
def cosine_lr(step, ocfg):
    warm = jnp.minimum(step.astype(F32) / max(ocfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step.astype(F32) - ocfg.warmup_steps)
                 / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    return ocfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale), grads), gn


def adamw_update(grads, opt_state, ocfg):
    """Returns (new_params_bf16, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = cosine_lr(count, ocfg)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1.0 - b1 ** count.astype(F32)
    bc2 = 1.0 - b2 ** count.astype(F32)
    grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)

    def upd(g, master, m, v):
        if ocfg.quantized_moments:
            mf = dequantize_moment(m, g.shape)
            vf = dequantize_moment(v, g.shape)
        else:
            mf, vf = m, v
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mhat = mf / bc1
        vhat = vf / bc2
        step = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        new_master = master - lr * (step + ocfg.weight_decay * master)
        if ocfg.quantized_moments:
            m_out = quantize_moment(mf, ocfg.moment_block)
            v_out = quantize_moment(vf, ocfg.moment_block)
        else:
            m_out, v_out = mf, vf
        return new_master, m_out, v_out

    flat_g, tdef = jax.tree.flatten(grads)
    flat_ma = tdef.flatten_up_to(opt_state["master"])
    is_q = lambda x: isinstance(x, dict) and "q" in x
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_q)[0] \
        if ocfg.quantized_moments else tdef.flatten_up_to(opt_state["m"])
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_q)[0] \
        if ocfg.quantized_moments else tdef.flatten_up_to(opt_state["v"])

    new_master, new_m, new_v = [], [], []
    for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v):
        nm, mm, vv = upd(g, ma, m, v)
        new_master.append(nm)
        new_m.append(mm)
        new_v.append(vv)

    new_state = {
        "master": jax.tree.unflatten(tdef, new_master),
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "count": count,
    }
    new_params = jax.tree.map(lambda ma: ma.astype(jnp.bfloat16),
                              new_state["master"])
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
