from repro.optim.adamw import (adamw_init, adamw_update, cosine_lr,
                               clip_by_global_norm, opt_state_logical_specs)

__all__ = ["adamw_init", "adamw_update", "cosine_lr",
           "clip_by_global_norm", "opt_state_logical_specs"]
