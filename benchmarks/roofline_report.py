"""§Roofline report + Fig. 4 analogue — reads artifacts/dryrun/*.json (the
compiled dry-run measurements) and prints (a) the full per-cell roofline
table, (b) the HAQ before/after roofline move for decode layer classes."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row
from repro.configs import get_config
from repro.core import haq
from repro.core.hardware_model import V5E_EDGE

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def main():
    recs = sorted(ART.glob("*__single.json"))
    for p in recs:
        r = json.loads(p.read_text())
        rf = r["roofline"]
        name = f"roofline/{r['arch']}__{r['shape']}"
        derived = (f"bottleneck={rf['bottleneck']};"
                   f"t_comp={rf['t_compute_s']:.4f}s;"
                   f"t_mem={rf['t_memory_s']:.4f}s;"
                   f"t_coll={rf['t_collective_s']:.4f}s;"
                   f"useful={rf['useful_flops_ratio']:.3f};"
                   f"mfu_bound={rf['mfu_bound']:.3f}")
        row(name, rf["t_compute_s"] * 1e6, derived)

    # Fig. 4: operation intensity before (bf16) and after HAQ (mixed bits)
    cfg = get_config("granite-3-8b")
    sites = haq.enumerate_sites(cfg, batch=1, seq=4096, decode=True)
    for s in sites[:6]:
        i16 = float(s.cost.intensity(16, 16))
        i4 = float(s.cost.intensity(4, 8))
        t16 = s.latency(V5E_EDGE, 16, 16) * 1e6
        t4 = s.latency(V5E_EDGE, 4, 8) * 1e6
        row(f"fig4/{s.name}", t16,
            f"intensity_bf16={i16:.1f};intensity_haq={i4:.1f};"
            f"lat_bf16_us={t16:.2f};lat_haq_us={t4:.2f};"
            f"gain={t16 / max(t4, 1e-12):.2f}x")


if __name__ == "__main__":
    main()
