"""Table 3 — AMC speeds up the model: prune a trained tiny LM to 50% FLOPs
(and a 50%-latency variant), report simulated TPU latency, memory, and
quality before/after (the paper's MobileNet 1.81x/1.95x rows)."""
from __future__ import annotations

import jax

from benchmarks.common import row, time_call, trained_tiny_model
from repro.core import amc
from repro.core.hardware_model import V5E_EDGE, linear_cost


def model_latency_bytes(model, ratios, layers):
    """Simulated per-token decode latency + weight bytes at given keep
    ratios (attention heads scale qkv/o; ffn units scale both matmuls)."""
    cfg = model.cfg
    d, hd = cfg.d_model, cfg.resolved_head_dim
    lat, mem = 0.0, 0.0
    for layer, r in zip(layers, ratios):
        if layer.kind == "attn":
            c = linear_cost(1, d, int((cfg.num_heads + 2 * cfg.num_kv_heads)
                                      * hd * r))
            c2 = linear_cost(1, int(cfg.num_heads * hd * r), d)
        else:
            c = linear_cost(1, d, int(cfg.d_ff * r) * 3)
            c2 = linear_cost(1, int(cfg.d_ff * r), d)
        n = cfg.num_layers
        lat += float(c.latency(V5E_EDGE) + c2.latency(V5E_EDGE)) * n
        mem += float(c.weight_bytes + c2.weight_bytes) * n
    return lat * 1e6, mem / 2**20


def main():
    model, params, val = trained_tiny_model()
    eval_loss = jax.jit(lambda p: model.loss(p, val))
    base_loss = float(eval_loss(params))
    layers = amc.enumerate_layers(model, tokens=4096)

    lat0, mem0 = model_latency_bytes(model, [1.0] * len(layers), layers)
    us0 = time_call(eval_loss, params)
    row("table3/dense-100pct", us0,
        f"loss={base_loss:.3f};sim_lat_us={lat0:.2f};weights_MiB={mem0:.2f}")

    for target, tag in [(0.5, "amc-50pct-flops"), (0.4, "amc-50pct-latency")]:
        res = amc.search(model, params, eval_loss,
                         amc.AMCConfig(target=target, episodes=24))
        ratios = res["best"]["ratios"]
        masked = amc.apply_ratios(params, layers, ratios)
        us = time_call(eval_loss, masked)
        lat, mem = model_latency_bytes(model, ratios, layers)
        row(f"table3/{tag}", us,
            f"loss={res['best']['loss']:.3f};sim_lat_us={lat:.2f};"
            f"weights_MiB={mem:.2f};speedup={lat0 / lat:.2f}x;"
            f"flops={res['best']['flops_frac']:.2f}")


if __name__ == "__main__":
    main()
