"""Table 5 — the best quantization policy for one hardware is not optimal on
another (paper's HW1/HW2/HW3 matrix).

TPU adaptation (DESIGN.md §2): unlike BitFusion's bit-serial PEs, TPU matmul
latency is a step function of bits (int8 MXU), so a *latency* budget only
discriminates on memory-bound regimes. Each target therefore constrains its
own binding resource — exactly the paper's point that the hardware's
characteristics shape the policy:
  HW1 edge-decode  : LATENCY budget (memory-bound, bits ~ linear win)
  HW2 pod-prefill  : ENERGY budget  (compute-bound; energy tracks bits)
  HW3 2pod-capacity: SIZE budget    (HBM capacity bound)
The cross matrix reports each policy's resource usage under every target's
constraint, normalized to that target's budget (<=1 means feasible). The
diagonal must be feasible; off-diagonal cells generally are not.
"""
from __future__ import annotations


from benchmarks.common import (make_traced_policy_loss, row,
                               trained_tiny_model)
from repro.core import haq
from repro.core.hardware_model import V5E_2POD, V5E_EDGE, V5E_POD
from repro.configs import get_config

TARGETS = {
    "HW1-edge-lat": (V5E_EDGE, dict(batch=1, seq=4096, decode=True),
                     "latency", 0.6),
    "HW2-pod-energy": (V5E_POD, dict(batch=8, seq=4096, decode=False),
                       "energy", 0.55),
    "HW3-2pod-size": (V5E_2POD, dict(batch=32, seq=4096, decode=False),
                      "size", 0.45),
}
FULL_ARCH = "granite-3-8b"


def main():
    model, params, val = trained_tiny_model(FULL_ARCH)
    cfg_full = get_config(FULL_ARCH)
    site_sets = {n: haq.enumerate_sites(cfg_full, **kw)
                 for n, (hw, kw, mode, frac) in TARGETS.items()}
    names = [s.name for s in next(iter(site_sets.values()))]
    eval_policy = make_traced_policy_loss(model, params, val, set(names))

    budgets, policies, losses = {}, {}, {}
    for n, (hw, kw, mode, frac) in TARGETS.items():
        sites = site_sets[n]
        base = haq.resource(sites, [(8, 8)] * len(sites), hw, mode)
        budgets[n] = frac * base
        res = haq.search(cfg_full, sites, eval_policy,
                         haq.HAQConfig(episodes=20, budget_frac=frac,
                                       mode=mode, seed=1), hw=hw)
        policies[n] = res["best"]["policy"]
        losses[n] = res["best"]["loss"]

    for pn, pol in policies.items():
        cells = {}
        for tn, (hw, kw, mode, frac) in TARGETS.items():
            wa = [pol.get(s.name, (8, 8)) for s in site_sets[tn]]
            used = haq.resource(site_sets[tn], wa, hw, mode)
            cells[tn] = used / budgets[tn]
        derived = ";".join(f"{t}={cells[t]:.2f}xbudget" for t in TARGETS)
        row(f"table5/policy-for-{pn}", cells[pn] * 100,
            derived + f";loss={losses[pn]:.4f};"
            f"feasible_on_own_hw={cells[pn] <= 1.001}")


if __name__ == "__main__":
    main()
