"""Table 4 — learning-based AMC vs rule-based uniform shrink at equal FLOPs
(the paper's MobileNet-V1/V2 uniform-multiplier comparison)."""
from __future__ import annotations

import jax

from benchmarks.common import row, time_call, trained_tiny_model
from repro.core import amc


def main():
    for arch in ("granite-3-8b", "granite-moe-3b-a800m"):
        model, params, val = trained_tiny_model(arch)
        eval_loss = jax.jit(lambda p, m=model, v=val: m.loss(p, v))
        base = float(eval_loss(params))
        for target in (0.5, 0.7):
            uni = amc.uniform_baseline(model, params, eval_loss, keep=target)
            res = amc.search(model, params, eval_loss,
                             amc.AMCConfig(target=target, episodes=24))
            us = time_call(eval_loss, params)
            d_uni = uni["loss"] - base
            d_amc = res["best"]["loss"] - base
            row(f"table4/{arch}-flops{int(target*100)}", us,
                f"base={base:.3f};d_uniform={d_uni:+.4f};d_amc={d_amc:+.4f};"
                f"amc_wins={d_amc <= d_uni + 1e-4}")


if __name__ == "__main__":
    main()
