"""Table 7 — agent transfer: HAQ agents trained on arch A, applied (no
further training) to arch B, vs direct search on B and fixed PACT."""
from __future__ import annotations


from benchmarks.common import (make_traced_policy_loss, row,
                               trained_tiny_model)
from repro.core import haq
from repro.core.hardware_model import V5E_EDGE
from repro.configs import get_config

KW = dict(batch=1, seq=4096, decode=True)


def setup(arch):
    model, params, val = trained_tiny_model(arch)
    cfg = get_config(arch)
    sites = haq.enumerate_sites(cfg, **KW)
    names = {s.name for s in sites}
    return cfg, sites, make_traced_policy_loss(model, params, val, names)


def main():
    cfg_a, sites_a, eval_a = setup("granite-3-8b")
    cfg_b, sites_b, eval_b = setup("llava-next-mistral-7b")

    res_a = haq.search(cfg_a, sites_a, eval_a,
                       haq.HAQConfig(episodes=20, budget_frac=0.6, seed=3),
                       hw=V5E_EDGE)
    res_b = haq.search(cfg_b, sites_b, eval_b,
                       haq.HAQConfig(episodes=20, budget_frac=0.6, seed=3),
                       hw=V5E_EDGE)
    # transfer: reuse A's agents on B's env with ZERO episodes of training
    env_b = haq.HAQEnv(cfg_b, sites_b, eval_b,
                       haq.HAQConfig(budget_frac=0.6), hw=V5E_EDGE)
    transfer = env_b.rollout(*res_a["agents"], explore=False)

    pact = {s.name: (4, 4) for s in sites_b}
    loss_pact = eval_b(pact)
    row("table7/pact-4bit", 0.0, f"loss={loss_pact:.4f}")
    row("table7/direct-search-B", 0.0,
        f"loss={res_b['best']['loss']:.4f}")
    row("table7/transfer-A-to-B", 0.0,
        f"loss={transfer['loss']:.4f};"
        f"close_to_direct={transfer['loss'] <= res_b['best']['loss'] + 0.1};"
        f"beats_pact={transfer['loss'] <= loss_pact + 1e-4}")


if __name__ == "__main__":
    main()
