"""Table 6 — latency-constrained HAQ vs fixed-bitwidth PACT on edge & cloud:
at the latency of uniform k-bit PACT, HAQ's mixed policy should lose less
quality (paper: +2-5 points top-1 at matched latency)."""
from __future__ import annotations


from benchmarks.common import (make_traced_policy_loss, row,
                               trained_tiny_model)
from repro.core import haq
from repro.core.hardware_model import V5E_EDGE, V5E_POD
from repro.configs import get_config

ARCH = "granite-3-8b"


def main():
    model, params, val = trained_tiny_model(ARCH)
    cfg = get_config(ARCH)
    for hw, kw, tag in [
        (V5E_EDGE, dict(batch=1, seq=4096, decode=True), "edge"),
        (V5E_POD, dict(batch=8, seq=4096, decode=False), "cloud"),
    ]:
        sites = haq.enumerate_sites(cfg, **kw)
        names = {s.name for s in sites}
        eval_policy = make_traced_policy_loss(model, params, val, names)
        loss_fp = eval_policy({n: (16, 16) for n in names})
        for bits in (4, 6, 8):
            pact = {s.name: (bits, max(bits, 4)) for s in sites}
            lat_pact = haq.resource(sites, [pact[s.name] for s in sites],
                                    hw, "latency")
            loss_pact = eval_policy(pact)
            res = haq.search(cfg, sites, eval_policy,
                             haq.HAQConfig(episodes=20,
                                           latency_budget=lat_pact, seed=2),
                             hw=hw)
            loss_haq = res["best"]["loss"]
            lat_haq = res["best"]["resource"]
            row(f"table6/{tag}-pact{bits}b", lat_pact * 1e6,
                f"loss={loss_pact:.4f};fp_loss={loss_fp:.4f}")
            row(f"table6/{tag}-haq@{bits}b-budget", lat_haq * 1e6,
                f"loss={loss_haq:.4f};haq_wins={loss_haq <= loss_pact + 1e-4}")


if __name__ == "__main__":
    main()
