"""Engine throughput — continuous batching vs the sequential baseline,
lazy page allocation + preemption vs upfront reservation, and fp vs
quantized KV-cache pools at equal HBM budget.

Results are also written to ``BENCH_engine.json`` (see ``--out``) so the
perf trajectory stays machine-readable across PRs; every trace RNG is
seeded explicitly (TRACE_SEEDS).

Four traces on the tiny CPU config:

  * **mixed** (16 requests, Poisson arrivals, Poisson-ish length mix):
    served sequentially through `launch.serve.generate` (B=1, one request
    at a time — the pre-engine path) and through the continuous-batching
    engine; greedy outputs are asserted token-identical. Both decode
    through the same paged-attention walk, so the speedup isolates the
    serving machinery: continuous batching plus the engine's jitted
    per-bucket prefill (the baseline prefills eagerly per request, as it
    always has).

  * **skewed** (long-``max_new`` tail on a page pool sized for the
    *expected*, not worst-case, footprint): served twice through the
    engine — once with the legacy upfront reservation
    (``ceil((prompt+max_new)/page)`` pages claimed at admission, which
    gates admission on pages most requests never touch) and once with
    lazy growth + youngest-first preemption. The derived column reports
    each mode's aggregate decode tokens/s; lazy wins because short
    requests slot into pages the long tail had only *nominally* reserved.

  * **kv-quant** (the skewed shape on a page pool capped by a fixed HBM
    *byte* budget): served through the engine with the fp pool, the int8
    pool, and the HAQ-searched mixed policy (serving/kvquant; local-window
    slots int4, global slots int8). All three pools get the same KV byte
    budget, so the quantized pools hold ~2x / ~2.3x the pages — fewer
    preemptions, more resident sequences, higher aggregate decode tok/s.
    The fp pool is the exactness baseline; quantized modes additionally
    report teacher-forced max-abs logit drift (kvquant.greedy_drift) and
    the greedy token-match fraction against fp.

  * **sharded** (the mixed shape served twice: 1-device vs an SPMD mesh —
    model=2 plus whatever data axis the forced host devices allow): greedy
    outputs are asserted token-identical (the sharded engine's acceptance
    bar), decode tok/s is recorded for both (host-device collectives make
    the sharded number a correctness trace, not a speedup, off-TPU), and
    the roofline capacity story is captured from ``derive_policy``:
    pool pages and resident sequences per device at 1 vs 2 model shards
    (the >=1.9x floor the CI gate enforces). Skipped (with a note) when
    fewer than 2 devices are visible — the multi-device CI job forces 8.

  * **longprompt** (a few short residents decoding for the whole run while
    long prompts keep a prefill in flight): served twice through the
    engine — whole-prompt buckets vs chunked prefill at a fixed chunk.
    Reports decode tok/s, per-decode-tick stall p50/p99 (the seconds a
    tick's already-ready sequences waited on prefill work,
    ``Engine.stall_log``), and TTFT p50/p99. Greedy outputs are asserted
    identical; the chunked mode must cut stall p99 >= 2x at equal decode
    tok/s (±10%) — the acceptance bar the CI bench-gate re-checks from
    the JSON.

The chunked long-prompt engine additionally contributes a ``telemetry``
section: measured per-decode-tick stall p50/p99 from the telemetry
record, per-kind tick counts, and the roofline predicted-vs-measured
calibration (`serving/telemetry/calibrate.py`) — the scale factors and
relative error that say how far `core/hardware_model`'s roofline is
from this host. ``--trace-out`` dumps the same engine's full tick trace
and request spans as Chrome trace-event JSON (Perfetto-loadable); the
CI engine-smoke job uploads it as a workflow artifact.

Engines are warmed on the exact trace shapes and re-timed on the same
instance, so jit compiles are excluded. Outputs are asserted identical
between the two admission modes (and to the sequential baseline on the
mixed trace).

Run: ``PYTHONPATH=src python -m benchmarks.bench_engine_throughput``.
CI: the engine-smoke job reruns the default (baseline-size) traces and
diffs the fresh JSON against the committed one via
``scripts/check_bench_regression.py``; the kv-quant job smokes
``--kv-requests 4`` separately.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs import tiny_config
from repro.core.hardware_model import V5E_EDGE
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.serving.engine import Engine, Request, derive_policy
from repro.serving.engine.admission import kv_bytes_per_token
from repro.serving.kvquant import greedy_drift, search_kv_policy
from repro.serving.telemetry import calibrate, write_chrome_trace

ARCH = "gemma2-2b"
MAX_BATCH = 8          # CPU-host cap on the policy's in-flight batch
PROMPT_MEAN = 24       # Poisson means for the mixed-trace length mix
GEN_MEAN = 24
ARRIVAL_RATE = 200.0   # req/s — a heavy-traffic burst

SKEW_MAX_LEN = 128     # skewed trace: model len, 8 pages of 16 per seq
SKEW_NUM_PAGES = 17    # 16 usable — two worst-case sequences' worth

LONG_MAX_LEN = 1024    # long-prompt trace: model len
LONG_PROMPT_LEN = 960  # the prompt whose prefill stalls resident decodes
LONG_CHUNK = 64        # fixed chunk so the stall bound is reproducible
LONG_RESIDENTS = 3     # short requests decoding for the whole run
LONG_RESIDENT_GEN = 224

# explicit trace seeds: the JSON trajectory is only comparable across PRs
# if every trace is reproducible
TRACE_SEEDS = {"mixed": 0, "skewed": 1, "kv": 2, "long": 3, "autotune": 4}

AUTOTUNE_BUDGET = 48   # default search budget (objective evaluations)
AUTOTUNE_TOPK = 3      # searched candidates re-measured on the real trace


def make_trace(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / ARRIVAL_RATE, n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        S = int(np.clip(rng.poisson(PROMPT_MEAN), 4, 48))
        gen = int(np.clip(rng.poisson(GEN_MEAN), 4, 48))
        prompt = rng.integers(2, cfg.vocab_size, S).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=float(arrivals[i])))
    return reqs


def make_skewed_trace(cfg, n, seed=1):
    """Short prompts; every other request asks for a long generation. Under
    upfront reservation the long tail's worst-case pages throttle
    admission; lazily they are claimed only as decode reaches them."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        S = int(rng.integers(4, 13))
        if i % 2:
            gen = int(rng.integers(64, SKEW_MAX_LEN - S - 8))
        else:
            gen = int(rng.integers(8, 17))
        prompt = rng.integers(2, cfg.vocab_size, S).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen))
    return reqs


def run_sequential(model, params, reqs):
    outs = {}
    t0 = time.monotonic()
    for r in reqs:       # FIFO, honoring arrival offsets
        wait = r.arrival - (time.monotonic() - t0)
        if wait > 0:
            time.sleep(wait)
        out = generate(model, params, jnp.asarray(r.prompt[None]), r.max_new)
        outs[r.rid] = np.asarray(jax.block_until_ready(out)[0])
    return outs, time.monotonic() - t0


def build_engine(model, params, *, max_model_len=96, reserve_upfront=False,
                 num_pages=None, max_batch=MAX_BATCH, prefill_chunk=None,
                 chunked_prefill=True):
    policy = derive_policy(model.cfg, V5E_EDGE,
                           max_model_len=max_model_len,
                           param_bytes=model.param_bytes())
    policy = dataclasses.replace(
        policy, max_batch=max_batch,
        **({"num_pages": num_pages} if num_pages else {}),
        **({"prefill_chunk": prefill_chunk} if prefill_chunk else {}))
    return Engine(model, params, policy, reserve_upfront=reserve_upfront,
                  chunked_prefill=chunked_prefill)


def timed_run(engine, reqs, *, realtime):
    """Warm on the exact trace, then re-time the same engine instance."""
    engine.run(reqs, realtime=realtime)
    engine.reset_stats()
    t0 = time.monotonic()
    outs = engine.run(reqs, realtime=realtime)
    return outs, time.monotonic() - t0, engine.stats


def bench_mixed(model, params, cfg, n):
    reqs = make_trace(cfg, n, seed=TRACE_SEEDS["mixed"])
    total_gen = sum(r.max_new for r in reqs)
    run_sequential(model, params, reqs)          # warm the baseline
    base_outs, base_dt = run_sequential(model, params, reqs)
    engine = build_engine(model, params)
    eng_outs, eng_dt, stats = timed_run(engine, reqs, realtime=True)

    for r in reqs:
        assert np.array_equal(base_outs[r.rid], eng_outs[r.rid]), (
            f"engine output diverged from sequential baseline for "
            f"request {r.rid}")

    base_tps = total_gen / base_dt
    eng_tps = total_gen / eng_dt
    speedup = eng_tps / base_tps
    row("engine/sequential-baseline", base_dt / total_gen * 1e6,
        f"tok_s={base_tps:.1f}")
    row("engine/continuous-batching", eng_dt / total_gen * 1e6,
        f"tok_s={eng_tps:.1f};ticks={stats['decode_ticks']}")
    row("engine/speedup", eng_dt * 1e6,
        f"speedup={speedup:.2f}x;target>=3x;pass={speedup >= 3.0}")
    print(f"# continuous batching: {eng_tps:.1f} tok/s vs sequential "
          f"{base_tps:.1f} tok/s -> {speedup:.2f}x (outputs identical)",
          flush=True)
    return {"n": n, "sequential_tok_s": base_tps, "engine_tok_s": eng_tps,
            "speedup": speedup}


def bench_skewed(model, params, cfg, n):
    reqs = make_skewed_trace(cfg, n, seed=TRACE_SEEDS["skewed"])
    results = {}
    for mode, upfront in (("upfront", True), ("lazy", False)):
        engine = build_engine(model, params, max_model_len=SKEW_MAX_LEN,
                              num_pages=SKEW_NUM_PAGES,
                              reserve_upfront=upfront)
        outs, dt, stats = timed_run(engine, reqs, realtime=False)
        tps = stats["decode_tokens"] / dt
        results[mode] = (outs, tps)
        row(f"engine/skewed-{mode}", dt / max(stats["decode_tokens"], 1)
            * 1e6,
            f"decode_tok_s={tps:.1f};ticks={stats['decode_ticks']};"
            f"preempt={stats['preemptions']};grown={stats['grown_pages']}")
    for r in reqs:
        assert np.array_equal(results["upfront"][0][r.rid],
                              results["lazy"][0][r.rid]), (
            f"lazy/preempting engine diverged from upfront reservation "
            f"for request {r.rid}")
    gain = results["lazy"][1] / results["upfront"][1]
    # the >1x target applies at the default trace size — tiny CI smokes
    # (few requests) don't pressure the pool, so the flag is informational
    row("engine/skewed-lazy-vs-upfront", gain,
        f"speedup={gain:.2f}x;n={n};target>1x@n>=12;"
        f"pass={gain > 1.0 or n < 12}")
    print(f"# lazy paging: {results['lazy'][1]:.1f} decode tok/s vs "
          f"upfront {results['upfront'][1]:.1f} -> {gain:.2f}x "
          f"(outputs identical)", flush=True)
    return {"n": n, "upfront_decode_tok_s": results["upfront"][1],
            "lazy_decode_tok_s": results["lazy"][1], "gain": gain}


def make_long_trace(cfg, n, seed=3):
    """A few short prompts that decode for the whole run (the decode-SLO
    population) plus ``n`` long-prompt short-generation requests that keep
    a prefill in flight almost continuously. Under whole-prompt prefill
    every long admission stalls the residents for the full prompt's
    forward; chunked prefill bounds the per-tick stall at one chunk."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(LONG_RESIDENTS):
        prompt = rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=LONG_RESIDENT_GEN))
    for i in range(n):
        prompt = rng.integers(2, cfg.vocab_size,
                              LONG_PROMPT_LEN).astype(np.int32)
        reqs.append(Request(rid=LONG_RESIDENTS + i, prompt=prompt,
                            max_new=16))
    return reqs


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def telemetry_section(engine, n):
    """The ``telemetry`` block of BENCH_engine.json, read off the chunked
    long-prompt engine (the trace with all three tick kinds in flight):
    measured stall percentiles from the telemetry record and the roofline
    predicted-vs-measured calibration per tick kind — the scale factors
    and relative error `telemetry.calibrate` fits for hardware_model."""
    tel = engine.telemetry
    m = tel.metrics
    report = calibrate(tel.ticks)
    stall_ms = [s * 1e3 for s in tel.stall_log_view()]
    sec = {
        "n": n,
        "ticks": {k: c.value for k, c in sorted(m.counters.items())
                  if k.startswith("ticks.")},
        "stall_p50_ms": _pct(stall_ms, 50),
        "stall_p99_ms": _pct(stall_ms, 99),
        "pool_min_free": m.gauge("pool.min_free").value,
        "roofline_scale": report.scale_factors(),
        "roofline_rel_err": report.rel_err_by_kind(),
    }
    scale = sec["roofline_scale"]
    rel = sec["roofline_rel_err"]
    row("engine/telemetry-calibration",
        sum(v for v in rel.values() if v is not None),
        ";".join(f"{k}:scale="
                 + ("-" if scale[k] is None else f"{scale[k]:.2f}")
                 + ",relerr="
                 + ("-" if rel[k] is None else f"{rel[k]:.2f}")
                 for k in sorted(scale)))
    print(f"# telemetry: {len(tel.ticks)} tick events, stall p99 "
          f"{sec['stall_p99_ms']:.1f}ms; roofline scale "
          + ", ".join(f"{k}={scale[k]:.2f}" for k in sorted(scale)
                      if scale[k] is not None), flush=True)
    return sec


def bench_longprompt(model, params, cfg, n):
    """Whole-prompt vs chunked prefill on the long-prompt trace: decode
    tok/s, per-decode-tick stall p50/p99 (engine.stall_log), and TTFT.

    Both modes run the same prefill-with-cache forward; "whole" sets the
    chunk to the model length, so every prompt lands in ONE tick — exactly
    the pre-chunking stall behaviour — while keeping the code path (and
    therefore the greedy outputs, asserted token-identical) shared, so the
    comparison isolates *chunking* rather than kernel numerics. The legacy
    bucketed forward (``chunked_prefill=False``) stays covered for
    exactness in tests/test_engine.py and test_chunked_prefill.py."""
    reqs = make_long_trace(cfg, n, seed=TRACE_SEEDS["long"])
    out = {"n": n, "prompt_len": LONG_PROMPT_LEN, "chunk": LONG_CHUNK}
    results = {}
    chunked_engine = None
    for mode, chunk in (("whole", LONG_MAX_LEN), ("chunked", LONG_CHUNK)):
        engine = build_engine(model, params, max_model_len=LONG_MAX_LEN,
                              max_batch=LONG_RESIDENTS + 1,
                              prefill_chunk=chunk)
        if mode == "chunked":
            chunked_engine = engine
        outs, dt, stats = timed_run(engine, reqs, realtime=False)
        stall_ms = [s * 1e3 for s in engine.stall_log]
        ttft_ms = [t * 1e3 for t in engine.first_token_s.values()]
        tps = stats["decode_tokens"] / dt
        rec = {"decode_tok_s": tps,
               "decode_ticks": stats["decode_ticks"],
               "prefill_chunks": stats["prefill_chunks"],
               "stall_p50_ms": _pct(stall_ms, 50),
               "stall_p99_ms": _pct(stall_ms, 99),
               "stall_max_ms": max(stall_ms) if stall_ms else 0.0,
               "ttft_p50_ms": _pct(ttft_ms, 50),
               "ttft_p99_ms": _pct(ttft_ms, 99)}
        results[mode] = outs
        out[mode] = rec
        row(f"engine/longprompt-{mode}",
            dt / max(stats["decode_tokens"], 1) * 1e6,
            f"decode_tok_s={tps:.1f};stall_p99_ms={rec['stall_p99_ms']:.1f};"
            f"ttft_p50_ms={rec['ttft_p50_ms']:.0f};"
            f"chunks={stats['prefill_chunks']}")
    for r in reqs:
        assert np.array_equal(results["whole"][r.rid],
                              results["chunked"][r.rid]), (
            f"chunked prefill diverged from whole-prompt prefill for "
            f"request {r.rid}")
    red = out["whole"]["stall_p99_ms"] / max(out["chunked"]["stall_p99_ms"],
                                             1e-9)
    ratio = out["chunked"]["decode_tok_s"] / out["whole"]["decode_tok_s"]
    out["stall_p99_reduction"] = red
    out["decode_tok_s_ratio"] = ratio
    row("engine/longprompt-stall-reduction", red,
        f"reduction={red:.2f}x;tok_s_ratio={ratio:.2f};"
        f"target>=2x@ratio+-10%;pass={red >= 2.0 and 0.9 <= ratio}")
    print(f"# chunked prefill: decode-stall p99 "
          f"{out['chunked']['stall_p99_ms']:.1f}ms vs whole-prompt "
          f"{out['whole']['stall_p99_ms']:.1f}ms ({red:.2f}x lower) at "
          f"{ratio:.2f}x decode tok/s (outputs identical)", flush=True)
    return out, chunked_engine


def _equal_budget_pages(cfg, kv_bits, page_size=16):
    """Pages a fixed KV byte budget holds at a given bit policy — the fp
    pool's SKEW_NUM_PAGES worth of bytes, re-sliced at quantized width."""
    budget = (SKEW_NUM_PAGES - 1) * page_size * kv_bytes_per_token(cfg)
    return int(budget // (page_size * kv_bytes_per_token(cfg, kv_bits))) + 1


def bench_kv(model, params, cfg, n):
    """fp vs int8 vs HAQ-mixed KV pools at equal HBM byte budget."""
    reqs = make_skewed_trace(cfg, n, seed=TRACE_SEEDS["kv"])
    haq = search_kv_policy(cfg, V5E_EDGE, max_model_len=SKEW_MAX_LEN,
                           episodes=0, budget_frac=0.4)
    modes = {"fp16": None, "int8": 8, "haq": haq["bits"]}
    out = {"haq_policy": haq["policy"], "n": n}
    fp_outs = None
    fp_replay = None     # one fp teacher-forced replay shared by all modes
    for name, bits in modes.items():
        pages = _equal_budget_pages(cfg, bits)
        policy = derive_policy(cfg, V5E_EDGE, max_model_len=SKEW_MAX_LEN,
                               param_bytes=model.param_bytes(),
                               kv_bits=bits)
        policy = dataclasses.replace(policy, max_batch=MAX_BATCH,
                                     num_pages=pages)
        engine = Engine(model, params, policy)
        outs, dt, stats = timed_run(engine, reqs, realtime=False)
        tps = stats["decode_tokens"] / dt
        rec = {"kv_bits": bits if bits is None or isinstance(bits, int)
               else list(bits),
               "num_pages": pages, "decode_tok_s": tps,
               "preemptions": stats["preemptions"],
               "decode_ticks": stats["decode_ticks"]}
        if fp_outs is None:
            fp_outs = outs
        else:
            match = total = 0
            for r in reqs:
                S = len(r.prompt)
                a, b = fp_outs[r.rid][S:], outs[r.rid][S:]
                match += int(np.sum(a == b))
                total += len(a)
            drift = greedy_drift(model, params, fp_outs[reqs[0].rid],
                                 len(reqs[0].prompt), kv_bits=bits,
                                 fp_logits=fp_replay)
            fp_replay = drift["fp_logits"]
            rec["token_match"] = match / max(total, 1)
            rec["logit_drift_max_abs"] = drift["max_abs"]
        out[name] = rec
        row(f"engine/kv-{name}",
            dt / max(stats["decode_tokens"], 1) * 1e6,
            f"decode_tok_s={tps:.1f};pages={pages};"
            f"preempt={stats['preemptions']};"
            + (f"match={rec.get('token_match', 1.0):.2f};"
               f"drift={rec.get('logit_drift_max_abs', 0.0):.3f}"
               if name != "fp16" else "baseline=fp16"))
    for name in ("int8", "haq"):
        gain = out[name]["decode_tok_s"] / out["fp16"]["decode_tok_s"]
        out[name]["gain_vs_fp"] = gain
        print(f"# kv-{name}: {out[name]['decode_tok_s']:.1f} decode tok/s "
              f"({gain:.2f}x fp) at {out[name]['num_pages']} vs "
              f"{out['fp16']['num_pages']} pages, drift "
              f"{out[name]['logit_drift_max_abs']:.3f}, token match "
              f"{out[name]['token_match']:.2f}", flush=True)
    return out


def bench_autotune(model, params, cfg, n, *, budget, topk, config_out):
    """The serving-stack autotuner on a mixed-shape trace: calibrate the
    roofline on the hand-picked default config's warmup run, search the
    engine config space on the scale-corrected roofline (DDPG +
    evolutionary, serving/autotune), re-measure the top-k candidates on
    the real engine, and ship the best *measured* config. Records
    searched vs default decode tok/s (the CI-gated floor: the winner may
    never measure below 0.95x the default — the default itself is in the
    validation set, so the search can only ever tie or win), TTFT p50
    for both, candidate counts, and the Spearman predicted-vs-measured
    rank correlation of the calibrated objective."""
    from repro.serving.autotune import (ConfigSpace, autotune_serving_config,
                                        save_serving_config)

    reqs = make_trace(cfg, n, seed=TRACE_SEEDS["autotune"])
    space = ConfigSpace(cfg, V5E_EDGE, max_model_len=96,
                        max_devices=jax.device_count(),
                        max_batch_cap=MAX_BATCH,
                        param_bytes=model.param_bytes())
    tune = autotune_serving_config(model, params, space, reqs,
                                   budget=budget, top_k=topk, seed=0)
    ratio = tune.searched_vs_default
    sec = {
        "n": n, "budget": budget, "top_k": topk,
        "method": tune.search.method, "seed": tune.search.seed,
        "candidates": tune.search.evaluated,
        "admissible": tune.search.admissible,
        "validated": len(tune.validated),
        "default": {
            "config": tune.default.scored.config.as_dict(),
            "decode_tok_s": tune.default.decode_tok_s,
            "ttft_p50_ms": tune.default.ttft_p50_s * 1e3,
        },
        "searched": {
            "config": tune.winner.scored.config.as_dict(),
            "decode_tok_s": tune.winner.decode_tok_s,
            "predicted_decode_tok_s":
                tune.winner.scored.pred_decode_tok_s,
            "ttft_p50_ms": tune.winner.ttft_p50_s * 1e3,
        },
        "searched_vs_default": ratio,
        "rank_correlation": tune.rank_correlation,
        "calibration_scale": dict(tune.scales.by_kind),
    }
    if config_out:
        save_serving_config(config_out, tune.record(space))
        print(f"# wrote searched serving config {config_out}", flush=True)
    corr = tune.rank_correlation
    row("engine/autotune-searched", ratio,
        f"searched_tok_s={tune.winner.decode_tok_s:.1f};"
        f"default_tok_s={tune.default.decode_tok_s:.1f};"
        f"ratio={ratio:.2f}x;candidates={tune.search.evaluated};"
        f"corr=" + ("-" if corr is None else f"{corr:.2f}")
        + f";target>=0.95x;pass={ratio >= 0.95}")
    print(f"# autotune: searched {tune.winner.decode_tok_s:.1f} decode "
          f"tok/s vs default {tune.default.decode_tok_s:.1f} "
          f"({ratio:.2f}x) over {tune.search.evaluated} candidates "
          f"({tune.search.admissible} admissible, "
          f"{len(tune.validated)} measured); rank corr "
          + ("n/a" if corr is None else f"{corr:.2f}")
          + f"; winner {tune.winner.scored.config.as_dict()}", flush=True)
    return sec


def bench_sharded(model, params, cfg, n):
    """1-device vs SPMD mesh on the mixed trace shape (same policy, same
    trace, outputs asserted identical) + mesh-aware admission capacity."""
    from repro.launch.mesh import make_serving_mesh

    ndev = jax.device_count()
    if ndev < 2:
        print("# sharded: skipped (1 visible device; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)", flush=True)
        return None
    tp = 2                                   # tiny gemma2 has K=2
    dp = max(min(ndev // tp, 4), 1)
    mesh = make_serving_mesh(model=tp, data=dp)
    reqs = make_trace(cfg, n, seed=TRACE_SEEDS["mixed"])
    results = {}
    out = {"n": n, "model_shards": tp, "data_shards": dp, "devices": ndev}
    for mode, m in (("one_dev", None), ("sharded", mesh)):
        policy = derive_policy(cfg, V5E_EDGE, max_model_len=96,
                               param_bytes=model.param_bytes())
        policy = dataclasses.replace(policy, max_batch=MAX_BATCH)
        engine = Engine(model, params, policy, mesh=m)
        outs, dt, stats = timed_run(engine, reqs, realtime=False)
        tps = stats["decode_tokens"] / dt
        results[mode] = outs
        out[mode] = {"decode_tok_s": tps,
                     "decode_ticks": stats["decode_ticks"]}
        row(f"engine/sharded-{mode}",
            dt / max(stats["decode_tokens"], 1) * 1e6,
            f"decode_tok_s={tps:.1f};ticks={stats['decode_ticks']}")
    identical = all(np.array_equal(results["one_dev"][r.rid],
                                   results["sharded"][r.rid]) for r in reqs)
    # recorded, not asserted: the CI gate (check_bench_regression.py
    # sharded floors) owns the failure so a divergence still produces the
    # JSON + comparison table instead of dying before --out is written
    out["outputs_identical"] = identical
    if not identical:
        print("# sharded: WARNING — outputs diverged from the 1-device "
              "engine (the bench gate will fail on this)", flush=True)

    # roofline capacity: per-device pool pages + resident sequences at
    # 1 vs 2 model shards in the same per-device HBM (the CI-gated floor)
    p1 = derive_policy(cfg, V5E_EDGE, max_model_len=96,
                       param_bytes=model.param_bytes())
    p2 = derive_policy(cfg, V5E_EDGE, max_model_len=96,
                       param_bytes=model.param_bytes(), mesh_model=2)
    out["capacity"] = {
        "pages_1shard": p1.num_pages, "pages_2shard": p2.num_pages,
        "pages_scaling_2x": p2.num_pages / p1.num_pages,
        "resident_1shard": p1.max_batch, "resident_2shard": p2.max_batch,
    }
    row("engine/sharded-capacity", out["capacity"]["pages_scaling_2x"],
        f"pages={p1.num_pages}->{p2.num_pages};"
        f"resident={p1.max_batch}->{p2.max_batch};target>=1.9x;"
        f"pass={out['capacity']['pages_scaling_2x'] >= 1.9}")
    print(f"# sharded: outputs identical on model={tp},data={dp}; "
          f"{out['sharded']['decode_tok_s']:.1f} vs "
          f"{out['one_dev']['decode_tok_s']:.1f} decode tok/s (host-device "
          f"mesh); pool pages {p1.num_pages}->{p2.num_pages} per device at "
          f"2 model shards "
          f"({out['capacity']['pages_scaling_2x']:.2f}x)", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="mixed-trace size (0 skips the section)")
    ap.add_argument("--skewed-requests", type=int, default=12,
                    help="skewed-trace size (0 skips the section)")
    ap.add_argument("--kv-requests", type=int, default=12,
                    help="kv-quant trace size (0 skips the section)")
    ap.add_argument("--long-requests", type=int, default=6,
                    help="long-prompt trace: number of long prompts "
                         "(0 skips the section)")
    ap.add_argument("--sharded-requests", type=int, default=6,
                    help="sharded trace size (0 skips; auto-skips with a "
                         "note when <2 devices are visible)")
    ap.add_argument("--autotune-requests", type=int, default=8,
                    help="autotune trace size (0 skips the section)")
    ap.add_argument("--autotune-budget", type=int, default=AUTOTUNE_BUDGET,
                    help="autotune search budget in objective evaluations")
    ap.add_argument("--autotune-topk", type=int, default=AUTOTUNE_TOPK,
                    help="searched candidates re-measured on the engine")
    ap.add_argument("--autotune-config-out", default="",
                    help="write the searched per-hardware serving config "
                         "JSON here ('' disables; load it back with "
                         "launch/serve.py --serving-config)")
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="machine-readable results file ('' disables)")
    ap.add_argument("--trace-out", default="",
                    help="write the chunked long-prompt engine's telemetry "
                         "as Chrome trace-event JSON to this path (open in "
                         "Perfetto; '' disables)")
    # parse_known_args: benchmarks/run.py invokes main() with its own tag
    # arguments still on sys.argv
    args, _ = ap.parse_known_args()

    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    results = {
        "schema": 1,
        "config": {"arch": ARCH, "tiny": True, "max_batch": MAX_BATCH,
                   "page_size": 16, "skew_max_len": SKEW_MAX_LEN,
                   "skew_num_pages": SKEW_NUM_PAGES,
                   "long_max_len": LONG_MAX_LEN,
                   "long_prompt_len": LONG_PROMPT_LEN,
                   "long_chunk": LONG_CHUNK,
                   "trace_seeds": TRACE_SEEDS},
    }
    if args.requests:
        results["mixed"] = bench_mixed(model, params, cfg, args.requests)
    if args.skewed_requests:
        results["skewed"] = bench_skewed(model, params, cfg,
                                         args.skewed_requests)
    if args.kv_requests:
        results["kv"] = bench_kv(model, params, cfg, args.kv_requests)
    if args.long_requests:
        longprompt, chunked_engine = bench_longprompt(model, params, cfg,
                                                      args.long_requests)
        results["longprompt"] = longprompt
        results["telemetry"] = telemetry_section(chunked_engine,
                                                 args.long_requests)
        if args.trace_out:
            write_chrome_trace(chunked_engine.telemetry, args.trace_out)
            print(f"# wrote Chrome trace {args.trace_out} "
                  f"(open in https://ui.perfetto.dev)", flush=True)
    if args.sharded_requests:
        sharded = bench_sharded(model, params, cfg, args.sharded_requests)
        if sharded is not None:
            results["sharded"] = sharded
    if args.autotune_requests:
        results["autotune"] = bench_autotune(
            model, params, cfg, args.autotune_requests,
            budget=args.autotune_budget, topk=args.autotune_topk,
            config_out=args.autotune_config_out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
