"""Engine throughput — continuous batching vs the sequential baseline.

A mixed-length 16-request trace (Poisson arrivals, Poisson-ish length mix)
is served twice on the tiny CPU config:

  * sequential: one request at a time through `launch.serve.generate`
    (B=1 dense cache) — the pre-engine serving path;
  * engine: continuous batching over the paged KV pool, admission from the
    edge-target roofline policy (batch capped for the CPU host).

Both paths are warmed on the exact trace shapes first so jit compiles are
excluded; the derived column reports aggregate generated tokens/s and the
speedup. Greedy outputs are asserted token-identical between the two
(engine exactness is also covered in tests/test_engine.py).

Run: ``PYTHONPATH=src python -m benchmarks.bench_engine_throughput``
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs import tiny_config
from repro.core.hardware_model import V5E_EDGE
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.serving.engine import Engine, Request, derive_policy

ARCH = "gemma2-2b"
N_REQUESTS = 16
MAX_BATCH = 8          # CPU-host cap on the policy's in-flight batch
PROMPT_MEAN = 24       # Poisson means for the length mix
GEN_MEAN = 24
ARRIVAL_RATE = 200.0   # req/s — a heavy-traffic burst


def make_trace(cfg, n=N_REQUESTS, seed=0):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / ARRIVAL_RATE, n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        S = int(np.clip(rng.poisson(PROMPT_MEAN), 4, 48))
        gen = int(np.clip(rng.poisson(GEN_MEAN), 4, 48))
        prompt = rng.integers(2, cfg.vocab_size, S).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=float(arrivals[i])))
    return reqs


def run_sequential(model, params, reqs):
    outs = {}
    t0 = time.monotonic()
    for r in reqs:       # FIFO, honoring arrival offsets
        wait = r.arrival - (time.monotonic() - t0)
        if wait > 0:
            time.sleep(wait)
        out = generate(model, params, jnp.asarray(r.prompt[None]), r.max_new)
        outs[r.rid] = np.asarray(jax.block_until_ready(out)[0])
    return outs, time.monotonic() - t0


def build_engine(model, params):
    policy = derive_policy(model.cfg, V5E_EDGE,
                           max_model_len=96,
                           param_bytes=model.param_bytes())
    policy = dataclasses.replace(policy, max_batch=MAX_BATCH)
    return Engine(model, params, policy)


def run_engine(model, params, reqs):
    engine = build_engine(model, params)
    t0 = time.monotonic()
    outs = engine.run(reqs, realtime=True)
    return outs, time.monotonic() - t0, engine.stats


def main():
    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = make_trace(cfg)
    total_gen = sum(r.max_new for r in reqs)

    # warm both paths on the trace shapes (compile excluded from timing)
    run_sequential(model, params, reqs)
    run_engine(model, params, reqs)

    base_outs, base_dt = run_sequential(model, params, reqs)
    eng_outs, eng_dt, stats = run_engine(model, params, reqs)

    for r in reqs:
        assert np.array_equal(base_outs[r.rid], eng_outs[r.rid]), (
            f"engine output diverged from sequential baseline for "
            f"request {r.rid}")

    base_tps = total_gen / base_dt
    eng_tps = total_gen / eng_dt
    speedup = eng_tps / base_tps
    row("engine/sequential-baseline", base_dt / total_gen * 1e6,
        f"tok_s={base_tps:.1f}")
    row("engine/continuous-batching", eng_dt / total_gen * 1e6,
        f"tok_s={eng_tps:.1f};ticks={stats['decode_ticks']}")
    row("engine/speedup", eng_dt * 1e6,
        f"speedup={speedup:.2f}x;target>=3x;pass={speedup >= 3.0}")
    print(f"# continuous batching: {eng_tps:.1f} tok/s vs sequential "
          f"{base_tps:.1f} tok/s -> {speedup:.2f}x (outputs identical)",
          flush=True)


if __name__ == "__main__":
    main()
