"""Shared helpers for the per-table benchmarks.

Output contract (benchmarks/run.py): every table prints CSV rows
``name,us_per_call,derived`` where us_per_call measures the benchmark's
representative jit'd call on this host and `derived` carries the
table-specific metric (loss delta, simulated latency, speedup, ...).
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import tiny_config
from repro.configs.base import OptimConfig, TrainConfig
from repro.core import quantization as q
from repro.data.pipeline import DataConfig
from repro.models.api import build_model
from repro.training import steps as steps_lib

F32 = jnp.float32


def time_call(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.4g},{derived}", flush=True)


def trained_tiny_model(arch: str = "granite-3-8b", steps: int = 120,
                       B: int = 8, S: int = 64, seed: int = 0):
    """A briefly-trained tiny model + eval batch (shared AMC/HAQ subject).
    Family-aware batches (vlm patches / encdec frames) via the pipeline."""
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import batch_for_model

    cfg = tiny_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(optim=OptimConfig(lr=5e-3, warmup_steps=4,
                                         total_steps=steps))
    state = steps_lib.init_train_state(model, tcfg, jax.random.PRNGKey(seed))
    step = jax.jit(steps_lib.make_train_step(model, tcfg))
    shape = ShapeConfig("bench", S, B, "train")
    dcfg = DataConfig(cfg.vocab_size, S, B, seed=seed)
    for s in range(steps):
        state, m = step(state, batch_for_model(model, shape, dcfg, s))
    val = batch_for_model(model, shape, dcfg, 10_000)
    return model, state["params"], val


def make_traced_policy_loss(model, params, batch, site_names):
    """One jit'd loss(policy_arrays) — bits are traced, so the HAQ episode
    loop never recompiles."""
    def loss_fn(policy):
        def dot(x, w, name):
            eq = q._einsum_for(x, w)
            if name not in policy:
                return jnp.einsum(eq, x, w)
            w_bits, a_bits = policy[name]
            wq = q.fake_quant_weight(w, w_bits)
            xq = q.fake_quant_act(x, a_bits)
            # bits >= 16 -> no-op (traced select)
            wq = jnp.where(w_bits >= 16, w.astype(wq.dtype), wq)
            xq = jnp.where(a_bits >= 16, x.astype(xq.dtype), xq)
            return jnp.einsum(eq, xq, wq)
        return model.loss(params, batch, dot=dot)

    jitted = jax.jit(loss_fn)

    def eval_policy(policy: Dict[str, Tuple[int, int]]) -> float:
        arr = {k: (jnp.asarray(v[0], F32), jnp.asarray(v[1], F32))
               for k, v in policy.items() if k in site_names}
        return float(jitted(arr))

    return eval_policy
