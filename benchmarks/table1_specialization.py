"""Table 1 / Fig. 2 — specialized model vs fixed baselines on the target
hardware (simulated TPU latency; quality = val CE on the synthetic task).

Baselines mirror the paper's: a uniform full-attention stack (the
"human-designed" reference), a uniform local stack ("small model"), and the
NAS-specialized architecture at a latency budget between the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.configs.supernet_lm import BACKBONE, CANDIDATE_OPS
from repro.core import latency_table as lt
from repro.core import nas
from repro.core import supernet as sn
from repro.core.hardware_model import V5E_POD


def tiny_backbone():
    cfg = BACKBONE.replace(num_layers=6, d_model=96, num_heads=4,
                           num_kv_heads=2, head_dim=24, d_ff=192,
                           vocab_size=512)
    return cfg.replace(ssm=cfg.ssm.__class__(
        d_state=16, expand=2, head_dim=48, n_groups=1, chunk=32))


def arch_latency(arch, lut):
    import numpy as np
    one_hot = jnp.asarray(np.eye(len(CANDIDATE_OPS))[
        [CANDIDATE_OPS.index(op) for op in arch]])
    return float(lt.sampled_latency(one_hot, lut)) * 1e6


def eval_arch(arch, cfg, data, steps=60):
    """Train a fixed (one-hot) architecture briefly, return val CE."""
    params, alpha = sn.init_supernet(jax.random.PRNGKey(1), cfg)
    gates = jnp.asarray([CANDIDATE_OPS.index(op) for op in arch])

    @jax.jit
    def step(params, batch):
        loss, g = jax.value_and_grad(sn.supernet_loss)(params, alpha, gates,
                                                       batch, cfg)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        return jax.tree.map(lambda p, x: p - (3e-2 * sc * x).astype(p.dtype),
                            params, g), loss

    for s in range(steps):
        params, _ = step(params, data(s))
    return float(sn.supernet_loss(params, alpha, gates, data(9999), cfg))


def main():
    cfg = tiny_backbone()
    data = nas.synthetic_lm_data(cfg, batch=4, seq=64)
    lut = lt.build_lut(cfg, 4, 64, V5E_POD)

    baselines = {
        "uniform-full-e4": ["attn_full_e4"] * cfg.num_layers,
        "uniform-full-e2": ["attn_full_e2"] * cfg.num_layers,
        "uniform-local1k-e2": ["attn_local1k_e2"] * cfg.num_layers,
    }
    # budget: between the cheap and expensive uniform baselines
    ref = 0.75 * arch_latency(baselines["uniform-full-e4"], lut) / 1e6
    res = nas.search(data, hw=V5E_POD,
                     ncfg=nas.NASConfig(steps=80, warmup_steps=30, batch=4,
                                        seq=64, alpha_lr=0.08, lat_ref=ref,
                                        log_every=40),
                     cfg=cfg, lut=lut)
    candidates = dict(baselines, **{"nas-specialized": res["arch"]})

    for name, arch in candidates.items():
        ce = eval_arch(arch, cfg, data)
        lat = arch_latency(arch, lut)
        row(f"table1/{name}", lat, f"val_ce={ce:.3f}")
    row("table1/nas-arch", res["e_lat_us"],
        "arch=" + "|".join(res["arch"]))


if __name__ == "__main__":
    main()
