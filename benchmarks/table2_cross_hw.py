"""Table 2 — hardware prefers specialized models: search one architecture per
hardware target, then evaluate every architecture's simulated latency on
every target (diagonal should win, as in the paper's GPU/CPU/mobile matrix).

Targets (TPU serving regimes, DESIGN.md §2):
  decode-edge   — 1 chip,   batch 1 decode      (memory-bound)
  prefill-pod   — 256 chips, batch 8 x 2048     (compute-bound)
  train-2pod    — 512 chips, slower cross-pod ICI (collective-sensitive)
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row
from benchmarks.table1_specialization import tiny_backbone, arch_latency
from repro.core import latency_table as lt
from repro.core import nas
from repro.core.hardware_model import V5E_2POD, V5E_EDGE, V5E_POD

TARGETS = {
    "decode-edge": (V5E_EDGE, dict(batch=1, seq=2048, decode=True)),
    "prefill-pod": (V5E_POD, dict(batch=8, seq=2048, decode=False)),
    "train-2pod": (V5E_2POD, dict(batch=8, seq=2048, decode=False)),
}


def main():
    cfg = tiny_backbone()
    data = nas.synthetic_lm_data(cfg, batch=4, seq=64)
    luts = {name: lt.build_lut(cfg, hw=hw, **kw)
            for name, (hw, kw) in TARGETS.items()}

    archs = {}
    for name, lut in luts.items():
        ref = 0.6 * float(lt.expected_latency(
            jnp.zeros((cfg.num_layers, lut.shape[1])), lut))
        res = nas.search(data, hw=TARGETS[name][0],
                         ncfg=nas.NASConfig(steps=60, warmup_steps=20,
                                            batch=4, seq=64, alpha_lr=0.08,
                                            lat_ref=ref, log_every=60),
                         cfg=cfg, lut=lut)
        archs[name] = res["arch"]

    # cross matrix, normalized per column: cell = slowdown vs the best arch
    # on that target (regimes have different absolute scales; the paper's
    # Table 2 point is the DIAGONAL wins its column)
    lats = {a: {t: arch_latency(arch, luts[t]) for t in TARGETS}
            for a, arch in archs.items()}
    col_best = {t: min(lats[a][t] for a in archs) for t in TARGETS}
    for a_name in archs:
        rel = {t: lats[a_name][t] / max(col_best[t], 1e-12) for t in TARGETS}
        derived = ";".join(f"{t}={rel[t]:.3f}x" for t in TARGETS)
        diag_wins = rel[a_name] <= min(rel.values()) + 1e-9
        row(f"table2/specialized-for-{a_name}",
            lats[a_name][a_name] * 1e3,  # ns
            derived + f";diagonal_best={diag_wins};"
            f"arch={'|'.join(archs[a_name][:6])}")


if __name__ == "__main__":
    main()
