"""Benchmark driver: one section per paper table. Prints
``name,us_per_call,derived`` CSV (see benchmarks/common.py).

Run: PYTHONPATH=src python -m benchmarks.run [table1 table3 ...]
"""
from __future__ import annotations

import sys
import time
import traceback

SECTIONS = [
    ("table1", "benchmarks.table1_specialization"),
    ("table2", "benchmarks.table2_cross_hw"),
    ("table3", "benchmarks.table3_amc_speedup"),
    ("table4", "benchmarks.table4_amc_vs_uniform"),
    ("table5", "benchmarks.table5_cross_hw_quant"),
    ("table6", "benchmarks.table6_haq_latency"),
    ("table7", "benchmarks.table7_transfer"),
    ("roofline", "benchmarks.roofline_report"),
    ("engine", "benchmarks.bench_engine_throughput"),
]


def main() -> None:
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = []
    for tag, mod_name in SECTIONS:
        if want and tag not in want:
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {tag} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((tag, repr(e)))
            traceback.print_exc()
            print(f"# {tag} FAILED: {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark sections failed")


if __name__ == "__main__":
    main()
