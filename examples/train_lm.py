"""End-to-end driver (assignment deliverable (b)): train a ~100M-param LM for
a few hundred steps on the deterministic synthetic pipeline, with
checkpoint/restart and straggler monitoring — the full production loop at
CPU scale.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

~100M config: 12L x d512 (GQA 8/4) x ff2048, vocab 32k -> 103M params.
"""
import argparse
import sys
import time

import jax

sys.path.insert(0, "src")

from repro.configs.base import (ModelConfig, OptimConfig, ShapeConfig,
                                TrainConfig)
from repro.models.api import build_model
from repro.training.loop import train

CFG_100M = ModelConfig(
    name="repro-lm-100m",
    family="dense",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    activation="swiglu",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    model = build_model(CFG_100M)
    print(f"{CFG_100M.name}: {model.param_count():,} params on "
          f"{jax.device_count()} device(s)")
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tcfg = TrainConfig(
        optim=OptimConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        checkpoint_dir=args.ckpt_dir, checkpoint_every=50, log_every=10)
    t0 = time.time()
    out = train(model, shape, tcfg, num_steps=args.steps)
    dt = time.time() - t0
    h = out["history"]
    toks = args.steps * args.batch * args.seq
    print(f"loss {h[0]['loss']} -> {h[-1]['loss']} in {dt:.0f}s "
          f"({toks / dt:.0f} tok/s); straggler events: "
          f"{len(out['straggler_events'])}")
    assert h[-1]["loss"] < h[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
