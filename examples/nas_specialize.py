"""Paper §2 end-to-end: search a specialized LM architecture for a chosen
TPU target with the path-binarized supernet + latency LUT, then train the
derived child and compare against the uniform baseline.

    PYTHONPATH=src python examples/nas_specialize.py --target decode-edge
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.supernet_lm import BACKBONE
from repro.core import latency_table as lt
from repro.core import nas
from repro.core.hardware_model import V5E_2POD, V5E_EDGE, V5E_POD

TARGETS = {
    "decode-edge": (V5E_EDGE, dict(batch=1, seq=2048, decode=True)),
    "prefill-pod": (V5E_POD, dict(batch=8, seq=2048, decode=False)),
    "train-2pod": (V5E_2POD, dict(batch=8, seq=2048, decode=False)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="decode-edge", choices=TARGETS)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--layers", type=int, default=6)
    args = ap.parse_args()

    cfg = BACKBONE.replace(num_layers=args.layers, d_model=96, num_heads=4,
                           num_kv_heads=2, head_dim=24, d_ff=192,
                           vocab_size=512)
    cfg = cfg.replace(ssm=cfg.ssm.__class__(d_state=16, expand=2, head_dim=48,
                                            n_groups=1, chunk=32))
    hw, kw = TARGETS[args.target]
    lut = lt.build_lut(cfg, hw=hw, **kw)
    print(f"searching {7 ** cfg.num_layers:,}-arch space for {args.target} "
          f"({hw.name})")
    res = nas.search(
        nas.synthetic_lm_data(cfg, batch=4, seq=64), hw=hw,
        ncfg=nas.NASConfig(steps=args.steps, warmup_steps=args.steps // 3,
                           batch=4, seq=64, alpha_lr=0.08,
                           log_every=max(args.steps // 4, 1)),
        cfg=cfg, lut=lut,
        progress=lambda r: print(f"  step {r['step']:4d} "
                                 f"ce={r['val_ce']:.3f} "
                                 f"E[lat]={r['e_lat_us']:.2f}us"))
    print(f"\nspecialized arch for {args.target}:")
    for i, op in enumerate(res["arch"]):
        print(f"  block {i:2d}: {op}")
    print(f"E[LAT] {res['e_lat_us']:.2f}us vs budget "
          f"{res['lat_ref_us']:.2f}us")


if __name__ == "__main__":
    main()
