"""Quickstart: build an assigned architecture, train it briefly on the
synthetic pipeline, checkpoint, restore, and serve a few tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]
"""
import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import get_config, tiny_config
from repro.configs.base import OptimConfig, ShapeConfig, TrainConfig
from repro.launch.serve import generate
from repro.models.api import build_model
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = tiny_config(args.arch)          # reduced same-family config (CPU)
    model = build_model(cfg)
    print(f"{cfg.name}: {model.param_count():,} params "
          f"(full config: {get_config(args.arch).param_count():,})")

    shape = ShapeConfig("quick", seq_len=64, global_batch=4, kind="train")
    tcfg = TrainConfig(optim=OptimConfig(lr=3e-3, total_steps=args.steps,
                                         warmup_steps=3),
                       checkpoint_dir="/tmp/repro_quickstart",
                       checkpoint_every=10, log_every=5)
    out = train(model, shape, tcfg, num_steps=args.steps)
    print(f"trained: loss {out['history'][0]['loss']} -> "
          f"{out['history'][-1]['loss']}")

    params = out["state"]["params"]
    prompt = jnp.ones((2, 16), jnp.int32)
    toks = generate(model, params, prompt, gen_len=8)
    print("generated:", jax.device_get(toks[0, 16:]))


if __name__ == "__main__":
    main()
