"""Paper §3+§4 push-the-button pipeline: train a tiny LM, AMC-prune it to a
FLOPs target, then HAQ-quantize the pruned model under an edge latency
budget, and serve with the quantized Pallas kernels.

    PYTHONPATH=src python examples/compress_pipeline.py
"""
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from benchmarks.common import make_traced_policy_loss, trained_tiny_model
from repro.core import amc, haq
from repro.core.hardware_model import V5E_EDGE
from repro.core.quantization import make_quant_dot
from repro.configs import get_config
from repro.launch.serve import generate


def main():
    print("=== stage 0: train subject model (tiny granite) ===")
    model, params, val = trained_tiny_model("granite-3-8b", steps=80)
    eval_loss = jax.jit(lambda p: model.loss(p, val))
    base = float(eval_loss(params))
    print(f"base val loss: {base:.4f}")

    print("=== stage 1: AMC auto-pruning to 60% FLOPs ===")
    res_amc = amc.search(model, params, eval_loss,
                         amc.AMCConfig(target=0.6, episodes=16))
    layers = amc.enumerate_layers(model, tokens=4096)
    pruned = amc.apply_ratios(params, layers, res_amc["best"]["ratios"])
    print(f"AMC: loss {base:.4f} -> {res_amc['best']['loss']:.4f} at "
          f"{res_amc['best']['flops_frac']:.2f}x FLOPs "
          f"(ratios={['%.2f' % r for r in res_amc['best']['ratios']]})")

    print("=== stage 2: HAQ mixed-precision quantization (edge budget) ===")
    cfg_full = get_config("granite-3-8b")
    sites = haq.enumerate_sites(cfg_full, batch=1, seq=4096, decode=True)
    names = {s.name for s in sites}
    eval_policy = make_traced_policy_loss(model, pruned, val, names)
    res_haq = haq.search(cfg_full, sites, eval_policy,
                         haq.HAQConfig(episodes=12, budget_frac=0.55),
                         hw=V5E_EDGE)
    pol = res_haq["best"]["policy"]
    print(f"HAQ policy: { {k: v for k, v in pol.items()} }")
    print(f"HAQ: loss {res_haq['best']['loss']:.4f} at "
          f"{res_haq['best']['resource'] * 1e6:.1f}us "
          f"(budget {res_haq['best']['budget'] * 1e6:.1f}us)")

    print("=== stage 3: serve the compressed model (Pallas int kernels) ===")
    dot = make_quant_dot({k: v for k, v in pol.items()}, use_kernel=True)
    prompt = jnp.ones((1, 16), jnp.int32)
    toks = generate(model, pruned, prompt, gen_len=8, dot=dot)
    print("served tokens:", jax.device_get(toks[0, 16:]))
    print("pipeline complete: prune -> quantize -> serve")


if __name__ == "__main__":
    main()
